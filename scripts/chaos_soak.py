"""Seeded chaos soak for the self-healing serving plane.

Repeatedly SIGKILLs spawn workers, hard-kills TCP shard-worker
subprocesses, RST-injects the TCP wire, and swaps the serving oracle —
all while a live pipelined HTTP replay runs with a retry policy — then
asserts the system healed completely:

  * ZERO lost requests across the whole soak (typed mid-wave 500s retry
    through the parent fallback);
  * every answered request matches exactly ONE oracle bit-exactly under
    its response epoch (no mixed-epoch waves through any recovery
    window);
  * at the end every worker slot is live again (each kill was adopted
    back, not left degraded) and a final clean replay — NO retry —
    answers everything.

The chaos schedule is drawn from one seed, defaulting to the current
git SHA's leading hex (so every CI commit soaks a different schedule);
a failing run prints the seed and is replayed with::

    PYTHONPATH=src python scripts/chaos_soak.py --seed 0x1213432a

Wire-level faults ride the same FaultPlan seed, so the socket chaos is
scripted too, not just the kill schedule.
"""
from __future__ import annotations

import argparse
import subprocess
import sys
import threading
import time

import numpy as np

from repro import api
from repro.core import workloads
from repro.core.predictor import ProfetConfig
from repro.serve import (BackgroundServer, LatencyService, LifecycleConfig,
                         RetryPolicy, ShardPlane, launch_tcp_workers,
                         replay, synthetic_requests)

RETRY = RetryPolicy(max_attempts=6, base_s=0.02, multiplier=2.0,
                    max_backoff_s=0.5, jitter=0.0, seed=0,
                    retry_statuses=frozenset({500, 503}))
HEAL_DEADLINE_S = 60.0


def _git_seed() -> int:
    try:
        sha = subprocess.run(["git", "rev-parse", "HEAD"],
                             capture_output=True, text=True).stdout.strip()
        return int(sha[:8], 16)
    except (OSError, ValueError):
        return int(time.time())


def _fit(seed: int) -> api.LatencyOracle:
    ds = workloads.generate(devices=("T4", "V100", "K80"),
                            models=("LeNet5", "AlexNet", "ResNet18"))
    cfg = ProfetConfig(members=("linear", "forest"), n_trees=20, seed=seed)
    return api.LatencyOracle.fit(ds, cfg)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Seeded kill/reset chaos soak (see module docstring).")
    ap.add_argument("--seed", type=lambda s: int(s, 0), default=None,
                    help="chaos schedule seed (default: git SHA prefix)")
    ap.add_argument("--rounds", type=int, default=6,
                    help="chaos events to inject")
    ap.add_argument("--requests", type=int, default=400,
                    help="requests per replay pass during the soak")
    ap.add_argument("--spawn-workers", type=int, default=2)
    ap.add_argument("--tcp-workers", type=int, default=2)
    args = ap.parse_args(argv)
    seed = args.seed if args.seed is not None else _git_seed()
    rng = np.random.default_rng(seed)
    print(f"chaos-soak: seed {seed:#x}  rounds {args.rounds}  "
          f"workers {args.spawn_workers} spawn + {args.tcp_workers} tcp",
          flush=True)

    oracle = _fit(0)
    fresh = _fit(7)
    oracle.warmup(max_rows=256)
    reqs = synthetic_requests(oracle, n=args.requests, seed=1)
    want = {}
    for orc, tag in ((oracle, "e1"), (fresh, "e2")):
        for i, res in enumerate(orc.predict_many(reqs)):
            want[(tag, i)] = res.latency_ms

    pool = launch_tcp_workers(args.tcp_workers)
    plane = None
    bg = None
    violations = []
    try:
        plane = ShardPlane(workers=args.spawn_workers, mode="spawn",
                           remote=pool.addresses)
        n_workers = plane.n_workers
        endpoints = {args.spawn_workers + j:
                     (lambda j=j: pool.respawn(j))
                     for j in range(args.tcp_workers)}
        svc = LatencyService(
            oracle, max_wave=32, cache_size=0, shard_plane=plane,
            supervise=LifecycleConfig(lease_interval_s=0.05,
                                      endpoints=endpoints))
        bg = BackgroundServer(svc, host="127.0.0.1", port=0).start()
        epoch_tag = {svc.epoch: "e1"}
        tag_lock = threading.Lock()

        stop = threading.Event()
        replayed = {"n": 0, "ok": 0}

        def pump():
            while not stop.is_set():
                rep = replay(bg.host, bg.port, reqs,
                             clients=4, retry=RETRY)
                replayed["n"] += rep["n"]
                replayed["ok"] += rep["ok"]
                if rep["ok"] != rep["n"]:
                    violations.append(
                        f"lost {rep['n'] - rep['ok']} requests "
                        f"({rep['errors'][:3]})")
                with tag_lock:
                    tags = dict(epoch_tag)
                for i, r in enumerate(rep["results"]):
                    if r is None:
                        continue
                    w = want[(tags[r["epoch"]], i)]
                    if r["latency_ms"] != w:
                        violations.append(
                            f"row {i} epoch {r['epoch']}: "
                            f"{r['latency_ms']} != {w}")

        pumper = threading.Thread(target=pump)
        pumper.start()

        # scripted chaos: every decision comes from the one seeded rng
        events = []
        for k in range(args.rounds):
            time.sleep(float(rng.uniform(0.1, 0.4)))
            kind = rng.choice(("kill-spawn", "kill-tcp", "swap"))
            if kind == "kill-spawn":
                i = int(rng.integers(0, args.spawn_workers))
                plane.workers[i].kill()
                events.append(f"kill-spawn:{i}")
            elif kind == "kill-tcp":
                j = int(rng.integers(0, args.tcp_workers))
                pool.kill(j)
                events.append(f"kill-tcp:{j}")
            else:
                orc, tag = ((fresh, "e2") if k % 2 == 0
                            else (oracle, "e1"))
                try:
                    ep = svc.oracle_refreshed(orc, f"{tag}.{k}")
                    with tag_lock:
                        epoch_tag[ep] = tag
                    events.append(f"swap:{tag}")
                except Exception as e:
                    # a swap racing a death may be rejected whole — the
                    # incumbent serves on, which the pump verifies
                    events.append(f"swap-rejected:{type(e).__name__}")
        print(f"chaos-soak: events {' '.join(events)}", flush=True)

        stop.set()
        pumper.join()

        # full recovery: every slot live again within the deadline
        deadline = time.monotonic() + HEAL_DEADLINE_S
        while time.monotonic() < deadline:
            if plane.alive_workers() == n_workers:
                break
            time.sleep(0.1)
        if plane.alive_workers() != n_workers:
            violations.append(
                f"only {plane.alive_workers()}/{n_workers} workers "
                f"recovered within {HEAL_DEADLINE_S}s")

        # final clean pass: no retry crutch, everything answers
        final = replay(bg.host, bg.port, reqs, clients=4)
        if final["ok"] != final["n"]:
            violations.append(
                f"final clean replay lost {final['n'] - final['ok']}")
        s = plane.summary()
        print(f"chaos-soak: {replayed['ok']}/{replayed['n']} soak "
              f"requests ok  adoptions {s['adoptions']}  "
              f"respawns {s['lifecycle']['respawns']}  "
              f"final {final['ok']}/{final['n']}  "
              f"alive {s['alive']}/{s['workers']}", flush=True)
    finally:
        if bg is not None:
            bg.stop()
        if plane is not None:
            plane.close()
        pool.close()

    if violations:
        print(f"chaos-soak FAILED (replay with --seed {seed:#x}):",
              file=sys.stderr)
        for v in violations[:10]:
            print(f"  {v}", file=sys.stderr)
        return 1
    print(f"chaos-soak ok (seed {seed:#x})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
