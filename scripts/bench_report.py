#!/usr/bin/env python
"""Print the bench-trajectory table from ``results/bench/BENCH_*.json``.

Each floor-gated benchmark (``bench_grid``, ``bench_fit``, ``bench_serve``,
``bench_transport``, ``bench_bank``, ``bench_calibrate``) writes one
machine-readable record per run — speedup, floor, wall time, git SHA — via
``benchmarks.common.save_bench``. CI uploads the records as a build
artifact; this script renders them so the perf trajectory is visible at a
glance in the job log.

When a PREVIOUS trajectory artifact is present (its ``BENCH_*.json`` files
dropped under ``results/bench/prev`` by default, or any directory named
with ``--prev``), the table adds a per-bench speedup delta column against
it — the at-a-glance answer to "did this commit move any gate".

    python scripts/bench_report.py [results/bench] [--prev DIR]
    python scripts/bench_report.py --gate [--prev DIR] [--regress-frac F]

Without ``--gate``, exit status is 0 even when a gate failed — the gate
itself already failed the bench stage; rendering is reporting only.

With ``--gate`` the trajectory becomes a merge gate: exit nonzero when
any bench record has ``passed: false`` or a speedup below its floor, or
when a bench regressed more than ``--regress-frac`` (default 0.20, i.e.
>20%) against the previous trajectory artifact. Benches the previous
artifact ran that are now missing are warned about but do not fail the
gate (a renamed or retired bench should not wedge CI); a previous
artifact that is absent entirely (first run, expired artifact) skips the
regression check and gates on floors alone.
"""
import json
import pathlib
import sys


def _records(out_dir: pathlib.Path):
    recs, bad = {}, []
    for path in sorted(out_dir.glob("BENCH_*.json")):
        try:
            rec = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            bad.append((path.name, f"unreadable: {e}"))
            continue
        recs[rec.get("benchmark", path.stem)] = rec
    return recs, bad


def _num(rec, key, fmt):
    """Render a numeric record field; '-' for absent/null/non-numeric
    values (a half-written record must not crash the report)."""
    try:
        return fmt.format(float(rec.get(key)))
    except (TypeError, ValueError):
        return "-"


def _fmt_delta(cur, prev):
    """Speedup delta vs the previous trajectory; a bench the previous
    artifact never ran is 'new' (no delta exists, not zero)."""
    if prev is None:
        return "new"
    try:
        d = float(cur.get("speedup")) - float(prev.get("speedup"))
    except (TypeError, ValueError):
        return "-"
    return f"{d:+.2f}x"


def rows_from(out_dir: pathlib.Path, prev_dir: pathlib.Path):
    recs, bad = _records(out_dir)
    prev, _ = _records(prev_dir) if prev_dir.is_dir() else ({}, [])
    rows = []
    for name, rec in recs.items():
        rows.append([
            name,
            _num(rec, "speedup", "{:.2f}x"),
            _fmt_delta(rec, prev.get(name)) if prev else "-",
            ">=" + _num(rec, "floor", "{:.1f}x"),
            "pass" if rec.get("passed") else "FAIL",
            _num(rec, "wall_s", "{:.1f}s"),
            str(rec.get("git_sha", "?")),
            str(rec.get("timestamp_iso", "?")),
        ])
    # benches the previous artifact ran but this one did not: surface them
    # as dropped instead of silently shrinking the table
    for name in sorted(set(prev) - set(recs)):
        rows.append([name, "-", "dropped",
                     ">=" + _num(prev[name], "floor", "{:.1f}x"),
                     "-", "-", str(prev[name].get("git_sha", "?")),
                     str(prev[name].get("timestamp_iso", "?"))])
    for name, why in bad:
        rows.append([name, "-", "-", "-", "-", "-", "-", why])
    return rows, bool(prev)


def fmt_table(rows, headers):
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows))
              for i, h in enumerate(headers)]
    line = lambda r: " | ".join(str(c).ljust(w) for c, w in zip(r, widths))
    sep = "-+-".join("-" * w for w in widths)
    return "\n".join([line(headers), sep] + [line(r) for r in rows])


def gate_violations(out_dir: pathlib.Path, prev_dir: pathlib.Path,
                    regress_frac: float):
    """The merge-gate rules over the trajectory records. Returns
    ``(violations, warnings)`` — human-readable strings."""
    recs, bad = _records(out_dir)
    prev, _ = _records(prev_dir) if prev_dir.is_dir() else ({}, [])
    violations = [f"{name}: {why}" for name, why in bad]
    warnings = []
    if not recs:
        violations.append(
            f"no BENCH_*.json records under {out_dir} — nothing to gate")
    for name, rec in sorted(recs.items()):
        try:
            speedup = float(rec.get("speedup"))
            floor = float(rec.get("floor"))
        except (TypeError, ValueError):
            violations.append(f"{name}: record has no numeric "
                              "speedup/floor")
            continue
        if not rec.get("passed"):
            violations.append(f"{name}: passed=false "
                              f"(speedup {speedup:.2f}x)")
        elif speedup < floor:
            violations.append(f"{name}: speedup {speedup:.2f}x below "
                              f"floor {floor:.1f}x")
        p = prev.get(name)
        if p is None:
            continue
        try:
            prev_speedup = float(p.get("speedup"))
        except (TypeError, ValueError):
            continue
        if prev_speedup > 0 and \
                speedup < prev_speedup * (1.0 - regress_frac):
            violations.append(
                f"{name}: speedup {speedup:.2f}x regressed "
                f">{regress_frac:.0%} vs previous trajectory "
                f"{prev_speedup:.2f}x")
    for name in sorted(set(prev) - set(recs)):
        warnings.append(f"{name}: present in previous trajectory but "
                        "not in this run (dropped?)")
    return violations, warnings


def main(argv=None):
    argv = list(argv if argv is not None else sys.argv[1:])
    usage = ("usage: bench_report.py [results/bench] [--prev DIR] "
             "[--gate] [--regress-frac F]")
    prev_dir = None
    gate = False
    regress_frac = 0.20
    if "--gate" in argv:
        gate = True
        argv.remove("--gate")
    if "--regress-frac" in argv:
        i = argv.index("--regress-frac")
        if i + 1 >= len(argv):
            print(usage)
            return 2
        regress_frac = float(argv[i + 1])
        del argv[i:i + 2]
    if "--prev" in argv:
        i = argv.index("--prev")
        if i + 1 >= len(argv):
            print(usage)
            return 2
        prev_dir = pathlib.Path(argv[i + 1])
        del argv[i:i + 2]
    out_dir = pathlib.Path(argv[0] if argv else "results/bench")
    if prev_dir is None:
        prev_dir = out_dir / "prev"
    rows, have_prev = rows_from(out_dir, prev_dir)
    if not rows and not gate:
        print(f"bench trajectory: no BENCH_*.json records under {out_dir} "
              "(run a bench_* --smoke gate first)")
        return 0
    if rows:
        vs = f" (delta vs {prev_dir})" if have_prev else ""
        print(f"bench trajectory ({out_dir}){vs}:")
        print(fmt_table(rows, ["benchmark", "speedup", "delta", "floor",
                               "gate", "wall", "git", "when"]))
    if not gate:
        return 0
    violations, warnings = gate_violations(out_dir, prev_dir,
                                           regress_frac)
    for w in warnings:
        print(f"gate warning: {w}")
    if violations:
        for v in violations:
            print(f"GATE FAIL: {v}")
        return 1
    prev_note = (f"regressions checked vs {prev_dir}" if have_prev
                 else "no previous trajectory — floors only")
    print(f"bench gate: all records pass their floors ({prev_note})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
