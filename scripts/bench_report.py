#!/usr/bin/env python
"""Print the bench-trajectory table from ``results/bench/BENCH_*.json``.

Each floor-gated benchmark (``bench_grid``, ``bench_fit``, ``bench_serve``,
``bench_transport``, ``bench_bank``, ``bench_calibrate``) writes one
machine-readable record per run — speedup, floor, wall time, git SHA — via
``benchmarks.common.save_bench``. CI uploads the records as a build
artifact; this script renders them so the perf trajectory is visible at a
glance in the job log.

When a PREVIOUS trajectory artifact is present (its ``BENCH_*.json`` files
dropped under ``results/bench/prev`` by default, or any directory named
with ``--prev``), the table adds a per-bench speedup delta column against
it — the at-a-glance answer to "did this commit move any gate".

    python scripts/bench_report.py [results/bench] [--prev DIR]

Exit status is 0 even when a gate failed — the gate itself already failed
the bench stage; this is reporting only.
"""
import json
import pathlib
import sys


def _records(out_dir: pathlib.Path):
    recs, bad = {}, []
    for path in sorted(out_dir.glob("BENCH_*.json")):
        try:
            rec = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            bad.append((path.name, f"unreadable: {e}"))
            continue
        recs[rec.get("benchmark", path.stem)] = rec
    return recs, bad


def _num(rec, key, fmt):
    """Render a numeric record field; '-' for absent/null/non-numeric
    values (a half-written record must not crash the report)."""
    try:
        return fmt.format(float(rec.get(key)))
    except (TypeError, ValueError):
        return "-"


def _fmt_delta(cur, prev):
    """Speedup delta vs the previous trajectory; a bench the previous
    artifact never ran is 'new' (no delta exists, not zero)."""
    if prev is None:
        return "new"
    try:
        d = float(cur.get("speedup")) - float(prev.get("speedup"))
    except (TypeError, ValueError):
        return "-"
    return f"{d:+.2f}x"


def rows_from(out_dir: pathlib.Path, prev_dir: pathlib.Path):
    recs, bad = _records(out_dir)
    prev, _ = _records(prev_dir) if prev_dir.is_dir() else ({}, [])
    rows = []
    for name, rec in recs.items():
        rows.append([
            name,
            _num(rec, "speedup", "{:.2f}x"),
            _fmt_delta(rec, prev.get(name)) if prev else "-",
            ">=" + _num(rec, "floor", "{:.1f}x"),
            "pass" if rec.get("passed") else "FAIL",
            _num(rec, "wall_s", "{:.1f}s"),
            str(rec.get("git_sha", "?")),
            str(rec.get("timestamp_iso", "?")),
        ])
    # benches the previous artifact ran but this one did not: surface them
    # as dropped instead of silently shrinking the table
    for name in sorted(set(prev) - set(recs)):
        rows.append([name, "-", "dropped",
                     ">=" + _num(prev[name], "floor", "{:.1f}x"),
                     "-", "-", str(prev[name].get("git_sha", "?")),
                     str(prev[name].get("timestamp_iso", "?"))])
    for name, why in bad:
        rows.append([name, "-", "-", "-", "-", "-", "-", why])
    return rows, bool(prev)


def fmt_table(rows, headers):
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows))
              for i, h in enumerate(headers)]
    line = lambda r: " | ".join(str(c).ljust(w) for c, w in zip(r, widths))
    sep = "-+-".join("-" * w for w in widths)
    return "\n".join([line(headers), sep] + [line(r) for r in rows])


def main(argv=None):
    argv = list(argv if argv is not None else sys.argv[1:])
    prev_dir = None
    if "--prev" in argv:
        i = argv.index("--prev")
        if i + 1 >= len(argv):
            print("usage: bench_report.py [results/bench] [--prev DIR]")
            return 2
        prev_dir = pathlib.Path(argv[i + 1])
        del argv[i:i + 2]
    out_dir = pathlib.Path(argv[0] if argv else "results/bench")
    if prev_dir is None:
        prev_dir = out_dir / "prev"
    rows, have_prev = rows_from(out_dir, prev_dir)
    if not rows:
        print(f"bench trajectory: no BENCH_*.json records under {out_dir} "
              "(run a bench_* --smoke gate first)")
        return 0
    vs = f" (delta vs {prev_dir})" if have_prev else ""
    print(f"bench trajectory ({out_dir}){vs}:")
    print(fmt_table(rows, ["benchmark", "speedup", "delta", "floor",
                           "gate", "wall", "git", "when"]))
    return 0


if __name__ == "__main__":
    sys.exit(main())
