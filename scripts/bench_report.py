#!/usr/bin/env python
"""Print the bench-trajectory table from ``results/bench/BENCH_*.json``.

Each floor-gated benchmark (``bench_grid``, ``bench_fit``, ``bench_serve``,
``bench_transport``) writes one machine-readable record per run — speedup,
floor, wall time, git SHA — via ``benchmarks.common.save_bench``. CI
uploads the records as a build artifact; this script renders them so the
perf trajectory is visible at a glance in the job log.

    python scripts/bench_report.py [results/bench]

Exit status is 0 even when a gate failed — the gate itself already failed
the bench stage; this is reporting only.
"""
import json
import pathlib
import sys


def rows_from(out_dir: pathlib.Path):
    rows = []
    for path in sorted(out_dir.glob("BENCH_*.json")):
        try:
            rec = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            rows.append([path.name, "-", "-", "-", "-", "-",
                         f"unreadable: {e}"])
            continue
        rows.append([
            rec.get("benchmark", path.stem),
            f"{rec.get('speedup', float('nan')):.2f}x",
            f">={rec.get('floor', float('nan')):.1f}x",
            "pass" if rec.get("passed") else "FAIL",
            f"{rec.get('wall_s', float('nan')):.1f}s",
            str(rec.get("git_sha", "?")),
            str(rec.get("timestamp_iso", "?")),
        ])
    return rows


def fmt_table(rows, headers):
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows))
              for i, h in enumerate(headers)]
    line = lambda r: " | ".join(str(c).ljust(w) for c, w in zip(r, widths))
    sep = "-+-".join("-" * w for w in widths)
    return "\n".join([line(headers), sep] + [line(r) for r in rows])


def main(argv=None):
    argv = argv if argv is not None else sys.argv[1:]
    out_dir = pathlib.Path(argv[0] if argv else "results/bench")
    rows = rows_from(out_dir)
    if not rows:
        print(f"bench trajectory: no BENCH_*.json records under {out_dir} "
              "(run a bench_* --smoke gate first)")
        return 0
    print(f"bench trajectory ({out_dir}):")
    print(fmt_table(rows, ["benchmark", "speedup", "floor", "gate",
                           "wall", "git", "when"]))
    return 0


if __name__ == "__main__":
    sys.exit(main())
