"""End-to-end training driver: a ~20M-param llama-family model for a few
hundred steps on CPU, with checkpointing, a simulated mid-run preemption,
and automatic recovery — the full fault-tolerance loop.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import dataclasses
import tempfile

from repro.configs import base as CB
from repro.train.fault_tolerance import FailureInjector, run_with_recovery
from repro.train.optimizer import OptHParams
from repro.train.trainer import Trainer, TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--width", type=int, default=256,
                    help="d_model; 768+ reaches the ~100M-param regime")
    args = ap.parse_args()

    # llama3.2-1b family shrunk to CPU scale (--width 768 ~ 100M params)
    cfg = dataclasses.replace(
        CB.get_config("llama3.2-1b", smoke=True),
        num_layers=4, d_model=args.width, num_heads=args.width // 64,
        num_kv_heads=max(args.width // 128, 1), d_ff=3 * args.width,
        vocab_size=4096, remat=False)
    print(f"model: {cfg.param_count()/1e6:.1f}M params "
          f"({cfg.num_layers}L d={cfg.d_model})")

    ckpt_dir = tempfile.mkdtemp(prefix="train_lm_ckpt_")
    injector = FailureInjector([args.steps // 2])   # preempt mid-run

    def make_trainer(attempt: int) -> Trainer:
        if attempt:
            print(f"--- restart #{attempt}: recovering from {ckpt_dir}")
        tc = TrainConfig(seq_len=args.seq, global_batch=args.batch,
                         num_steps=args.steps, log_every=25, ckpt_every=25,
                         ckpt_dir=ckpt_dir)
        hp = OptHParams(learning_rate=1e-3, warmup_steps=20,
                        decay_steps=args.steps)
        return Trainer(cfg, tc, hp=hp)

    report = run_with_recovery(make_trainer, args.steps, injector=injector)
    print(f"\ndone: {report.completed_steps} steps, "
          f"{report.restarts} restart(s) after preemption at "
          f"{report.preemptions}, final loss "
          f"{report.final_metrics['loss']:.4f}")


if __name__ == "__main__":
    main()
