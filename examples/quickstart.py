"""Quickstart: the two halves of this repo in ~60 seconds.

1. The FRAMEWORK: build an assigned architecture (reduced config), run a few
   training steps, decode a few tokens.
2. The PAPER (PROFET), through the public ``repro.api`` service layer. The
   whole prediction surface is three calls:

       oracle = api.LatencyOracle.fit(dataset, config)      # fit once
       api.save(oracle, path)                               # persist (versioned)
       api.load(path).predict(api.PredictRequest(...))      # query anywhere

   ``PredictRequest`` routes itself: given an exact-case anchor profile it
   runs phase-1 cross-instance prediction; without one it falls back to
   two-phase min/max interpolation — callers never pick min/max configs or
   thread raw tuples. ``predict_grid`` answers whole device x batch x pixel
   sweeps with one vectorized ensemble call per device.

    PYTHONPATH=src python examples/quickstart.py
"""
import pathlib
import tempfile

import jax

from repro import api
from repro.configs import base as CB
from repro.core import simulator, workloads
from repro.core.predictor import ProfetConfig
from repro.models import model as M
from repro.serve.engine import Engine
from repro.train.trainer import Trainer, TrainConfig


def framework_quickstart():
    print("=== framework: train + serve llama3.2-1b (reduced config) ===")
    cfg = CB.get_config("llama3.2-1b", smoke=True)
    trainer = Trainer(cfg, TrainConfig(seq_len=128, global_batch=8,
                                       num_steps=30, log_every=10))
    final = trainer.run()
    print(f"trained {cfg.param_count()/1e6:.2f}M params, "
          f"loss {trainer.history[0]['loss']:.3f} -> {final['loss']:.3f}")

    eng = Engine(cfg, trainer.params, batch_slots=2, max_len=64)
    r = eng.submit([1, 2, 3, 4], max_new_tokens=8)
    eng.run()
    print(f"decoded: {r.output}  ({eng.stats.tokens_per_s:.1f} tok/s)\n")


def profet_quickstart():
    print("=== PROFET: cross-instance latency prediction (repro.api) ===")
    # offline phase (the cloud vendor's job): fit an oracle on a small
    # workload grid and persist it through the versioned artifact store
    ds = workloads.generate(devices=("T4", "V100"),
                            models=("LeNet5", "AlexNet", "ResNet18", "VGG11"))
    train, test = workloads.split_cases(ds.cases, test_frac=0.2, seed=0)
    cfg = ProfetConfig(dnn_epochs=60, n_trees=30)
    oracle = api.LatencyOracle.fit(ds, cfg, train)
    path = pathlib.Path(tempfile.gettempdir()) / "profet_quickstart.pkl"
    api.save(oracle, path)

    # online phase (the client's job): profile ONCE on the anchor instance,
    # then query the stored oracle (fingerprint-checked against the config)
    oracle = api.load(path, expect_config=cfg)
    workload = api.Workload.from_case(test[0])
    meas = simulator.measure("T4", *workload.case)
    r = oracle.predict(api.PredictRequest("T4", "V100", workload,
                                          profile=meas.profile))
    true = ds.latency("V100", workload.case)
    print(f"workload {workload.case}: profiled on T4 "
          f"({meas.latency_ms:.1f} ms)")
    print(f"predicted on V100: {r.latency_ms:.1f} ms | actual: {true:.1f} ms "
          f"({100*abs(r.latency_ms-true)/true:.1f}% error)")
    print("(no model architecture was ever revealed — only op-name latency"
          " aggregates)")


if __name__ == "__main__":
    framework_quickstart()
    profet_quickstart()
