"""Batched serving demo: the wave-scheduled engine on two architecture
families — a dense transformer (KV cache) and an attention-free SSM
(constant-size state, the long-context family).

    PYTHONPATH=src python examples/serving.py
"""
import time

import jax
import numpy as np

from repro.configs import base as CB
from repro.models import model as M
from repro.serve.engine import Engine


def serve(arch: str, n_requests: int = 6, slots: int = 3):
    cfg = CB.get_config(arch, smoke=True)
    params, _ = M.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, batch_slots=slots, max_len=96)

    rng = np.random.default_rng(0)
    reqs = []
    for _ in range(n_requests):
        prompt = rng.integers(1, min(cfg.vocab_size, 200),
                              size=int(rng.integers(3, 10))).tolist()
        reqs.append(eng.submit(prompt, max_new_tokens=12))
    t0 = time.time()
    eng.run()
    dt = time.time() - t0

    s = eng.stats
    lat = [r.t_finish - r.t_submit for r in reqs]
    print(f"[{arch}] {n_requests} requests, {slots} slots -> {s.waves} waves")
    print(f"  generated {s.generated_tokens} tokens in {dt:.2f}s "
          f"({s.tokens_per_s:.1f} tok/s), "
          f"p50 latency {np.median(lat)*1e3:.0f} ms")
    print(f"  sample output: {reqs[0].output}")


if __name__ == "__main__":
    serve("llama3.2-1b")     # dense GQA + KV cache
    serve("mamba2-130m")     # SSM: O(1) state, no KV cache growth
