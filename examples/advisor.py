"""PROFET as a first-class framework feature: the instance advisor.

The paper's end-to-end scenario (Fig 3 + the serverless demo of §IV): a
client profiles a CNN training workload ONCE on the instance they already
have, and PROFET predicts latency + cost on every other instance in the
catalog — including devices newer than anything in the training set
(Table VI) and TPU chips (beyond paper).

    PYTHONPATH=src python examples/advisor.py
"""
import numpy as np

from repro.core import simulator, workloads
from repro.core.devices import CATALOG, PAPER_DEVICES, TPU_DEVICES, UNSEEN_DEVICES
from repro.core.predictor import Profet, ProfetConfig

ANCHOR = "T4"
WORKLOAD = ("ResNet50", 64, 128)   # model, batch, pixels
TRAIN_STEPS = 50_000


def main():
    print(f"fitting PROFET on the offline grid (anchors={ANCHOR}) ...")
    ds = workloads.generate()  # paper's 4 instances + unseen + TPU
    train, _ = workloads.split_cases(ds.cases, test_frac=0.2, seed=0)
    targets = PAPER_DEVICES + UNSEEN_DEVICES + ("TPUv5e",)
    prophet = Profet(ProfetConfig(dnn_epochs=100)).fit(
        ds, train, anchors=(ANCHOR,), targets=targets)

    meas = simulator.measure(ANCHOR, *WORKLOAD)
    print(f"\nworkload {WORKLOAD} profiled on {ANCHOR}: "
          f"{meas.latency_ms:.1f} ms/batch\n")
    print(f"{'device':8s} {'ms/batch':>9s} {'$/hr':>7s} "
          f"{'$/{:,} steps'.format(TRAIN_STEPS):>15s}")
    rows = []
    for name in targets:
        if name == ANCHOR:
            lat = meas.latency_ms
        else:
            lat = prophet.predict_cross(ANCHOR, name, meas.profile, WORKLOAD)
        cost = lat / 1e3 / 3600 * TRAIN_STEPS * CATALOG[name].price_hr
        rows.append((name, lat, cost))
        print(f"{name:8s} {lat:9.1f} {CATALOG[name].price_hr:7.3f} "
              f"{cost:15.3f}")
    fastest = min(rows, key=lambda r: r[1])
    cheapest = min(rows, key=lambda r: r[2])
    print(f"\n-> fastest: {fastest[0]}  |  cheapest: {cheapest[0]}")
    print("(the anchor profile reveals only (op name, aggregated ms) rows —")
    print(" the client's model architecture stays private)")


if __name__ == "__main__":
    main()
