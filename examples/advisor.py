"""PROFET as a first-class framework feature: the instance advisor.

The paper's end-to-end scenario (Fig 3 + the serverless demo of §IV): a
client profiles a CNN training workload ONCE on the instance they already
have, and PROFET predicts latency + cost on every other instance in the
catalog — including devices newer than anything in the training set
(Table VI) and TPU chips (beyond paper). All prediction goes through the
``repro.api`` facade: one ``advise`` call replaces the per-device
``predict_cross`` loop.

    PYTHONPATH=src python examples/advisor.py
"""
from repro import api
from repro.core import simulator, workloads
from repro.core.devices import PAPER_DEVICES, UNSEEN_DEVICES
from repro.core.predictor import ProfetConfig

ANCHOR = "T4"
WORKLOAD = api.Workload("ResNet50", 64, 128)
TRAIN_STEPS = 50_000


def main():
    print(f"fitting PROFET on the offline grid (anchors={ANCHOR}) ...")
    targets = PAPER_DEVICES + UNSEEN_DEVICES + ("TPUv5e",)
    # the seed version called generate() with its 4-device default and then
    # KeyError'd on the unseen targets — the grid must cover every target
    ds = workloads.generate(devices=targets)
    train, _ = workloads.split_cases(ds.cases, test_frac=0.2, seed=0)
    oracle = api.LatencyOracle.fit(ds, ProfetConfig(dnn_epochs=100), train,
                                   anchors=(ANCHOR,), targets=targets)

    meas = simulator.measure(ANCHOR, *WORKLOAD.case)
    print(f"\nworkload {WORKLOAD.case} profiled on {ANCHOR}: "
          f"{meas.latency_ms:.1f} ms/batch\n")
    print(f"{'device':8s} {'ms/batch':>9s} {'$/hr':>7s} "
          f"{'$/{:,} steps'.format(TRAIN_STEPS):>15s}")
    rows = oracle.advise(ANCHOR, WORKLOAD, profile=meas.profile,
                         measured_ms=meas.latency_ms, targets=targets)
    for r in rows:
        print(f"{r.target:8s} {r.latency_ms:9.1f} {r.price_hr:7.3f} "
              f"{r.cost_usd(TRAIN_STEPS):15.3f}")
    fastest = min(rows, key=lambda r: r.latency_ms)
    cheapest = min(rows, key=lambda r: r.cost_usd(TRAIN_STEPS))
    print(f"\n-> fastest: {fastest.target}  |  cheapest: {cheapest.target}")
    print("(the anchor profile reveals only (op name, aggregated ms) rows —")
    print(" the client's model architecture stays private)")


if __name__ == "__main__":
    main()
