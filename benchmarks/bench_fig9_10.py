"""Fig 9 (true-vs-predicted scatter per anchor) + Fig 10 (MAPE/RMSE/R2 of
Linear / RandomForest / DNN vs the PROFET median ensemble), plus the member-
selection counts the paper reports (25.8 / 32.8 / 41.4%)."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core.devices import PAPER_DEVICES
from repro.core.regressors import LinearRegressor


def run() -> dict:
    ds = common.dataset().subset(PAPER_DEVICES)
    train, test = common.split()
    oracle = common.paper_oracle()

    scatter = {}          # fig 9: per anchor, true/pred pairs over targets
    member_preds = {m: [] for m in ("linear", "forest", "dnn")}
    ens_preds, truths = [], []
    scalar_linear_preds = []   # fig 10's "Linear": anchor latency -> target

    counts = {m: 0 for m in ("linear", "forest", "dnn")}
    for ga in PAPER_DEVICES:
        pairs = []
        for gt in PAPER_DEVICES:
            if ga == gt:
                continue
            ens = oracle.ensemble(ga, gt)
            X = oracle.feature_matrix(ga, test)
            y = np.array([ds.latency(gt, c) for c in test])
            mp = ens.predict_members(X)
            pred = np.median(np.stack(list(mp.values())), axis=0)
            for m in member_preds:
                member_preds[m].append(mp[m])
            ens_preds.append(pred)
            truths.append(y)
            for m, c in ens.member_selection_counts(X).items():
                counts[m] += c
            pairs += list(zip(y.tolist(), pred.tolist()))

            # scalar-anchor-latency linear baseline (paper's Fig-10 Linear)
            xa_tr = np.array([[ds.latency(ga, c)] for c in train])
            ya_tr = np.array([ds.latency(gt, c) for c in train])
            xa_te = np.array([[ds.latency(ga, c)] for c in test])
            lin = LinearRegressor().fit(xa_tr, ya_tr)
            scalar_linear_preds.append(lin.predict(xa_te))
        scatter[ga] = pairs

    y_all = np.concatenate(truths)
    fig10 = {
        "Linear": common.metrics(y_all, np.concatenate(scalar_linear_preds)),
        "RandomForest": common.metrics(
            y_all, np.concatenate(member_preds["forest"])),
        "DNN": common.metrics(y_all, np.concatenate(member_preds["dnn"])),
        "PROFET": common.metrics(y_all, np.concatenate(ens_preds)),
    }
    total = sum(counts.values())
    selection = {m: 100.0 * c / total for m, c in counts.items()}

    out = {"fig9_scatter": scatter, "fig10": fig10,
           "member_selection_pct": selection}
    common.save("fig9_10", out)
    return {"profet_mape": fig10["PROFET"]["mape"],
            "profet_r2": fig10["PROFET"]["r2"],
            "dnn_mape": fig10["DNN"]["mape"],
            "linear_mape": fig10["Linear"]["mape"],
            "forest_mape": fig10["RandomForest"]["mape"],
            **{f"sel_{k}": v for k, v in selection.items()}}
