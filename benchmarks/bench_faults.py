"""Fault-injected replay resilience gate: the serving plane must keep
its throughput and tail latency through scripted chaos, losing zero
requests.

Two replays of the same mixed traffic against the HTTP transport:
a clean one, and one with a seeded ``FaultPlan`` injecting wave-execute
failures at FAULT_RATE plus short wave delays. Every request hit by an
injected fault must come back as a *typed* error (HTTP 500 Execution /
503 CircuitOpen) — never a hang, a dropped connection, or a silent loss.

Acceptance floors:
  - zero lost requests: answered + typed errors == total, in both runs
    (the clean run additionally has zero errors);
  - throughput under chaos >= THROUGHPUT_FLOOR x clean throughput —
    failing waves fast-fail instead of stalling the pump;
  - client p99 under chaos <= P99_SLACK x clean p99 (+1 ms).

    PYTHONPATH=src python -m benchmarks.bench_faults           # full
    PYTHONPATH=src python -m benchmarks.bench_faults --smoke   # CI gate
"""
from __future__ import annotations

import sys
import time

from repro import api
from repro.core import workloads
from repro.core.predictor import ProfetConfig
from repro.serve import (BackgroundServer, FaultInjector, FaultPlan,
                         FaultRule, LatencyService, replay,
                         synthetic_requests)
from repro.serve import faults as faults_mod

FAULT_RATE = 0.10         # Bernoulli wave-execute failure rate
DELAY_RATE = 0.10         # Bernoulli wave-delay rate
DELAY_S = 0.002
THROUGHPUT_FLOOR = 0.7    # chaos rps >= floor x clean rps
P99_SLACK = 3.0           # chaos p99 <= slack x clean p99 (+1 ms)
N_CLIENTS = 4


def _fit_oracle(smoke: bool) -> api.LatencyOracle:
    if smoke:
        ds = workloads.generate(devices=("T4", "V100"),
                                models=("LeNet5", "AlexNet", "ResNet18"))
        cfg = ProfetConfig(members=("linear", "forest"), n_trees=30, seed=0)
    else:
        ds = workloads.generate(
            devices=("T4", "V100", "K80", "M60"),
            models=("LeNet5", "AlexNet", "ResNet18", "VGG11", "ResNet50",
                    "MobileNetV2"))
        cfg = ProfetConfig(dnn_epochs=40, n_trees=60, seed=0)
    return api.LatencyOracle.fit(ds, config=cfg)


def _replay_once(oracle, reqs, faults=None) -> dict:
    svc = LatencyService(oracle, max_wave=16, faults=faults)
    bg = BackgroundServer(svc).start()
    try:
        rep = replay(bg.host, bg.port, reqs, clients=N_CLIENTS)
    finally:
        bg.stop()
    rep["stats"] = svc.stats.summary()
    return rep


def run(smoke: bool = False) -> dict:
    oracle = _fit_oracle(smoke)
    n = 160 if smoke else 400
    reqs = synthetic_requests(oracle, n=n, seed=13)

    clean = _replay_once(oracle, reqs)
    injector = FaultInjector(FaultPlan(rules=(
        FaultRule(site=faults_mod.SITE_EXECUTE, rate=FAULT_RATE),
        FaultRule(site=faults_mod.SITE_EXECUTE, kind=faults_mod.DELAY,
                  rate=DELAY_RATE, delay_s=DELAY_S)), seed=13))
    chaos = _replay_once(oracle, reqs, faults=injector)

    ratio = chaos["requests_per_s"] / max(clean["requests_per_s"], 1e-9)
    p99_ok = chaos["client_p99_ms"] <= P99_SLACK * clean["client_p99_ms"] + 1.0
    clean_lossless = clean["ok"] == clean["n"] and not clean["errors"]
    # chaos loses nothing: every request is answered or typed-failed
    chaos_lossless = (chaos["ok"] + len(chaos["errors"]) == chaos["n"]
                      and all(etype for _, etype in chaos["errors"]))
    injected = [f for f in injector.fired if f[1] == faults_mod.ERROR]
    out = {"smoke": smoke, "n": n, "clients": N_CLIENTS,
           "fault_rate": FAULT_RATE, "delay_rate": DELAY_RATE,
           "injected_errors": len(injected),
           "injected_delays": len(injector.fired) - len(injected),
           "clean_rps": clean["requests_per_s"],
           "chaos_rps": chaos["requests_per_s"],
           "throughput_ratio": ratio,
           "throughput_floor": THROUGHPUT_FLOOR,
           "clean_p99_ms": clean["client_p99_ms"],
           "chaos_p99_ms": chaos["client_p99_ms"], "p99_ok": p99_ok,
           "clean_ok": clean["ok"], "chaos_ok": chaos["ok"],
           "chaos_typed_errors": len(chaos["errors"]),
           "error_types": sorted({t for _, t in chaos["errors"]}),
           "clean_lossless": clean_lossless,
           "chaos_lossless": chaos_lossless,
           "chaos_stats": chaos["stats"]}
    from benchmarks import common
    common.save("faults", out)
    return out


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    smoke = "--smoke" in argv
    t0 = time.perf_counter()
    r = run(smoke=smoke)
    wall = time.perf_counter() - t0
    print(f"faults: {r['n']} requests x{r['clients']} clients  "
          f"{r['injected_errors']} injected wave faults "
          f"(+{r['injected_delays']} delays)")
    print(f"  throughput: clean {r['clean_rps']:.0f} rps -> chaos "
          f"{r['chaos_rps']:.0f} rps  (ratio {r['throughput_ratio']:.2f}, "
          f"floor {r['throughput_floor']:.1f})")
    print(f"  p99: clean {r['clean_p99_ms']:.2f} ms -> chaos "
          f"{r['chaos_p99_ms']:.2f} ms  (slack {P99_SLACK:.1f}x)")
    print(f"  accounting: {r['chaos_ok']} answered + "
          f"{r['chaos_typed_errors']} typed errors "
          f"{r['error_types']} == {r['n']}  "
          f"lossless={r['chaos_lossless']}")
    ok = (r["clean_lossless"] and r["chaos_lossless"]
          and r["throughput_ratio"] >= r["throughput_floor"]
          and r["p99_ok"])
    from benchmarks import common
    common.save_bench("faults", speedup=r["throughput_ratio"],
                      floor=r["throughput_floor"], wall_s=wall, passed=ok,
                      smoke=smoke,
                      extra={"chaos_lossless": r["chaos_lossless"],
                             "injected_errors": r["injected_errors"],
                             "chaos_typed_errors": r["chaos_typed_errors"],
                             "chaos_p99_ms": r["chaos_p99_ms"]})
    if not ok:
        print("FAIL: the serving plane did not hold its resilience floors "
              "under injected chaos (see record)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
