"""Multi-host sharded serving: 4 TCP-loopback shard workers vs the
single-worker banked path.

The multi-host twin of ``bench_shard``: the same catalog, but the
workers are real ``repro.launch.shard_worker`` subprocesses reached over
sockets — frame encode, codec, kernel, and all — so the number gated
here is the full remote-execution critical path, not a best case.

Three floor-gated claims:

  1. **Critical-path scaling** — one full-catalog wave through 4
     TCP-loopback workers vs ``ModelBank.execute`` in-process, measured
     exactly like ``bench_shard`` (CPU-time ``busy_s`` reported by each
     worker, parent share = wall − Σbusy, critical path = parent +
     max busy — honest on a single-core box where four processes can
     never win on wall-clock). Floor: >= 2.0x at 4 workers (lower than
     the shared-memory plane's 2.5x — the parent's share now includes
     frame encode + socket writes of every wave).
  2. **Bit-identity** — the gathered remote wave equals the
     single-worker banked wave bit-for-bit: the shard tensors crossed
     the wire as raw little-endian bytes, so nothing rounded.
  3. **Mixed pipelined replay** — an HTTP replay against the
     TCP-sharded service with pipelined clients: zero lost requests,
     client p99 within 3x of the single-worker clean p99.

    PYTHONPATH=src python -m benchmarks.bench_multihost           # full
    PYTHONPATH=src python -m benchmarks.bench_multihost --smoke   # CI
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np

from benchmarks.bench_shard import _fit_oracle, _wave_inputs
from repro import api
from repro.serve import (BackgroundServer, LatencyService, ShardPlane,
                         launch_tcp_workers, replay, synthetic_requests)

TARGET_SCALING = 2.0
P99_RATIO_FLOOR = 3.0
N_WORKERS = 4


def _row_plane(oracle: api.LatencyOracle, pool, smoke: bool) -> dict:
    n_rows = 6000 if smoke else 12000
    X, gids = _wave_inputs(oracle, n_rows)
    bank = oracle.bank
    reps = 7 if smoke else 5

    want = bank.execute(X, gids)           # warm the single-worker path
    singles = []
    for _ in range(reps):
        t0 = time.perf_counter()
        bank.execute(X, gids)
        singles.append(time.perf_counter() - t0)
    t_single = min(singles)

    with ShardPlane(workers=0, mode="thread",
                    remote=pool.addresses) as plane:
        sharded = plane.load(bank)
        got = sharded.execute(X, gids)     # warm workers (first touch)
        np.testing.assert_array_equal(got, want)   # gate 2: bit-identity
        walls, parents, busies = [], [], []
        for _ in range(reps):
            got = sharded.execute(X, gids)
            lw = sharded.last_wave
            busy = list(lw["busy_s"].values())
            walls.append(lw["wall_s"])
            parents.append(max(lw["wall_s"] - sum(busy), 0.0))
            busies.append(max(busy))
        np.testing.assert_array_equal(got, want)
        assert plane.slice_errors == 0 and plane.fallback_rows == 0
    # deterministic cost + scheduler noise that only inflates: best rep
    # of each component independently (same accounting as bench_shard)
    best_parent, best_busy = min(parents), min(busies)
    critical = best_parent + best_busy
    return {"rows": n_rows, "pairs": len(bank.pairs),
            "workers": N_WORKERS, "mode": "tcp-loopback",
            "cores": os.cpu_count(),
            "single_ms": 1e3 * t_single,
            "sharded_wall_ms": 1e3 * min(walls),
            "parent_ms": 1e3 * best_parent,
            "max_busy_ms": 1e3 * best_busy,
            "critical_path_ms": 1e3 * critical,
            "scaling": t_single / critical, "bit_identical": True}


def _replay_tier(oracle: api.LatencyOracle, pool, smoke: bool) -> dict:
    n_requests = 12_000 if smoke else 100_000
    base = synthetic_requests(oracle, n=500, seed=0)
    reqs = (base * (n_requests // len(base) + 1))[:n_requests]

    def drive(plane):
        svc = LatencyService(oracle, max_wave=64, shard_plane=plane)
        bg = BackgroundServer(svc, host="127.0.0.1", port=0).start()
        try:
            return replay(bg.host, bg.port, reqs, clients=8)
        finally:
            bg.stop()

    clean = drive(None)                    # single-worker baseline
    with ShardPlane(workers=0, mode="thread",
                    remote=pool.addresses) as plane:
        sharded = drive(plane)
        summary = plane.summary()
    lost = sharded["n"] - sharded["ok"]
    ratio = sharded["client_p99_ms"] / clean["client_p99_ms"]
    return {"n_requests": n_requests,
            "clean_p99_ms": clean["client_p99_ms"],
            "clean_rps": clean["requests_per_s"],
            "sharded_p99_ms": sharded["client_p99_ms"],
            "sharded_rps": sharded["requests_per_s"],
            "p99_ratio": ratio, "lost": lost,
            "slice_errors": summary["slice_errors"],
            "fallback_rows": summary["fallback_rows"]}


def run(smoke: bool = False) -> dict:
    oracle = _fit_oracle(smoke)
    oracle.warmup(max_rows=512)
    with launch_tcp_workers(N_WORKERS) as pool:
        rp = _row_plane(oracle, pool, smoke)
        rt = _replay_tier(oracle, pool, smoke)
    out = {"smoke": smoke, "row_plane": rp, "replay": rt,
           "target_scaling": TARGET_SCALING,
           "p99_ratio_floor": P99_RATIO_FLOOR}
    from benchmarks import common
    common.save("multihost", out)
    return out


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    smoke = "--smoke" in argv
    t0 = time.perf_counter()
    r = run(smoke=smoke)
    wall = time.perf_counter() - t0
    rp, rt = r["row_plane"], r["replay"]
    print(f"multihost: {rp['rows']} rows over {rp['pairs']} groups x "
          f"{rp['workers']} TCP-loopback workers ({rp['cores']} cores) "
          f"-> single {rp['single_ms']:.1f} ms  "
          f"critical path {rp['critical_path_ms']:.1f} ms "
          f"(parent {rp['parent_ms']:.1f} + busy {rp['max_busy_ms']:.1f})  "
          f"scaling {rp['scaling']:.2f}x (target >= {TARGET_SCALING}x)")
    print(f"           replay {rt['n_requests']} requests: "
          f"clean p99 {rt['clean_p99_ms']:.2f} ms  "
          f"sharded p99 {rt['sharded_p99_ms']:.2f} ms "
          f"(ratio {rt['p99_ratio']:.2f} <= {P99_RATIO_FLOOR})  "
          f"lost {rt['lost']}")
    ok = (rp["scaling"] >= TARGET_SCALING and rp["bit_identical"]
          and rt["lost"] == 0 and rt["p99_ratio"] <= P99_RATIO_FLOOR)
    from benchmarks import common
    common.save_bench(
        "multihost", speedup=rp["scaling"], floor=TARGET_SCALING,
        wall_s=wall, passed=ok, smoke=smoke,
        extra={"mode": rp["mode"], "workers": rp["workers"],
               "cores": rp["cores"], "bit_identical": rp["bit_identical"],
               "replay_requests": rt["n_requests"],
               "replay_lost": rt["lost"],
               "replay_p99_ratio": rt["p99_ratio"],
               "p99_ratio_floor": P99_RATIO_FLOOR})
    if not ok:
        print("FAIL: multi-host sharded serving under its floors")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
