"""Concurrent-client throughput of the HTTP transport vs sequential
round-trips — the wave-microbatching payoff measured at the socket.

Baseline = ONE client draining the mixed request stream serially: every
request is its own HTTP round-trip AND its own wave (plan + fused execute
for a single request). Concurrent = the same stream partitioned over N
keep-alive clients firing at once: requests arriving while a wave executes
batch into the next one, so the server answers the stream with far fewer
(and fatter) fused calls. Both phases run against a fresh server over the
same fitted oracle; every response must match the direct in-process
``predict_many`` answer element-wise. Acceptance floor: N concurrent
clients >= 3x the sequential client.

    PYTHONPATH=src python -m benchmarks.bench_transport           # full
    PYTHONPATH=src python -m benchmarks.bench_transport --smoke   # CI gate
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro import api
from repro.core import workloads
from repro.core.predictor import ProfetConfig
from repro.serve import (BackgroundServer, Client, LatencyService, replay,
                         synthetic_requests)

TARGET_SPEEDUP = 3.0
N_CLIENTS = 16
N_REQUESTS = 480          # divisible by N_CLIENTS
SEQ_REPS = 2              # min-of-reps, like the other floor gates
CONC_REPS = 4


def _fit_oracle(smoke: bool) -> api.LatencyOracle:
    if smoke:
        ds = workloads.generate(devices=("T4", "V100"),
                                models=("LeNet5", "AlexNet", "ResNet18"))
        cfg = ProfetConfig(members=("linear", "forest"), n_trees=30, seed=0)
    else:
        ds = workloads.generate(
            devices=("T4", "V100", "K80", "M60"),
            models=("LeNet5", "AlexNet", "ResNet18", "VGG11", "ResNet50",
                    "MobileNetV2"))
        cfg = ProfetConfig(dnn_epochs=40, n_trees=60, seed=0)
    return api.LatencyOracle.fit(ds, config=cfg)


def _serve(oracle, max_wave=64):
    svc = LatencyService(oracle, max_wave=max_wave)
    return svc, BackgroundServer(svc).start()


def _sequential(oracle, reqs) -> dict:
    """One client, one request in flight: every request is its own wave
    (admission window + plan + single-request fused execute + HTTP RT)."""
    svc, bg = _serve(oracle)
    try:
        with Client(bg.host, bg.port) as c:
            c.healthz()                       # connection + route warm
            t0 = time.perf_counter()
            results = [c.predict(r) for r in reqs]
            wall = time.perf_counter() - t0
        return {"wall_s": wall, "results": results,
                "stats": svc.stats.summary()}
    finally:
        bg.stop()


def _concurrent(oracle, reqs, clients) -> dict:
    svc, bg = _serve(oracle)
    try:
        rep = replay(bg.host, bg.port, reqs, clients=clients)
        rep["stats"] = svc.stats.summary()
        return rep
    finally:
        bg.stop()


def run(smoke: bool = False) -> dict:
    oracle = _fit_oracle(smoke)
    reqs = synthetic_requests(oracle, n=N_REQUESTS, seed=0)
    direct = oracle.predict_many(reqs)    # ground truth + jax warmup
    want = direct.latencies()

    # min-of-reps on both sides (each rep against a fresh server so the
    # prediction cache never carries over between phases)
    rtol = 1e-9 if smoke else 1e-5
    seq = conc = None
    for _ in range(SEQ_REPS):
        s = _sequential(oracle, reqs)
        if seq is None or s["wall_s"] < seq["wall_s"]:
            seq = s
    for _ in range(CONC_REPS):
        c = _concurrent(oracle, reqs, N_CLIENTS)
        assert c["ok"] == len(reqs) and not c["errors"]
        np.testing.assert_allclose([r["latency_ms"] for r in c["results"]],
                                   want, rtol=rtol)
        if conc is None or c["wall_s"] < conc["wall_s"]:
            conc = c

    # every socket response (both phases) equals the in-process answer
    np.testing.assert_allclose([r["latency_ms"] for r in seq["results"]],
                               want, rtol=rtol)
    assert [r["mode"] for r in conc["results"]] == \
        [r.mode for r in direct.results]

    speedup = seq["wall_s"] / conc["wall_s"]
    lat = np.array(conc["latencies_ms"])
    hist_edges = [0, 1, 2, 5, 10, 20, 50, 100, 1000, 10000]
    hist = np.histogram(lat, bins=hist_edges)[0]
    out = {"smoke": smoke, "n_requests": len(reqs), "clients": N_CLIENTS,
           "seq_s": seq["wall_s"], "conc_s": conc["wall_s"],
           "speedup": speedup, "target_speedup": TARGET_SPEEDUP,
           "seq_waves": seq["stats"]["waves"],
           "conc_waves": conc["stats"]["waves"],
           "seq_fused_calls": seq["stats"]["fused_calls"],
           "conc_fused_calls": conc["stats"]["fused_calls"],
           "client_p50_ms": conc["client_p50_ms"],
           "client_p99_ms": conc["client_p99_ms"],
           "latency_hist_edges_ms": hist_edges,
           "latency_hist": hist.tolist()}
    from benchmarks import common
    common.save("transport", out)
    return out


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    smoke = "--smoke" in argv
    t0 = time.perf_counter()
    r = run(smoke=smoke)
    wall = time.perf_counter() - t0
    print(f"transport: {r['n_requests']} requests  "
          f"1 client {r['seq_s']:.2f} s ({r['seq_waves']} waves)  "
          f"{r['clients']} clients {r['conc_s']:.2f} s "
          f"({r['conc_waves']} waves)  "
          f"speedup {r['speedup']:.1f}x (target >= "
          f"{r['target_speedup']:.0f}x)")
    print(f"  client latency p50 {r['client_p50_ms']:.2f} ms  "
          f"p99 {r['client_p99_ms']:.2f} ms  histogram "
          f"{dict(zip(r['latency_hist_edges_ms'], r['latency_hist']))}")
    from benchmarks import common
    ok = r["speedup"] >= r["target_speedup"]
    common.save_bench("transport", speedup=r["speedup"],
                      floor=r["target_speedup"], wall_s=wall, passed=ok,
                      smoke=smoke,
                      extra={"clients": r["clients"],
                             "client_p50_ms": r["client_p50_ms"],
                             "client_p99_ms": r["client_p99_ms"]})
    if not ok:
        print("FAIL: concurrent transport under the concurrency floor")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
