"""Multi-worker sharded wave execution vs the single-worker banked path.

Three floor-gated claims about ``repro.serve.shard``:

  1. **Row-plane scaling** — one full-catalog wave executed through a
     4-worker spawn ``ShardPlane`` vs ``ModelBank.execute`` in-process.
     This box may have a single CPU core, where four processes cannot
     beat one on wall-clock no matter how the work is cut, so the gate
     measures the **critical path** of the sharded wave: the workers
     report the time they spent busy inside their grouped launch
     (``busy_s``, measured worker-side), the parent's own share is
     ``wall - sum(busy)``, and the critical path — what the wave would
     cost with the shards genuinely concurrent — is
     ``parent + max(busy)``. Floor: >= 2.5x at 4 workers. The JSON
     records the mode and core count so the number can be read honestly.
  2. **Bit-identity** — the gathered sharded wave must equal the
     single-worker banked wave bit-for-bit (float64 members only here;
     sharding is pure group-axis slicing of the same tensors).
  3. **Sustained replay** — a mixed HTTP replay (>= 100k requests full,
     a smaller smoke tier) against the sharded service: zero lost
     requests, and client p99 within 3x of the single-worker clean p99.

    PYTHONPATH=src python -m benchmarks.bench_shard           # full
    PYTHONPATH=src python -m benchmarks.bench_shard --smoke   # CI gate
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np

from repro import api
from repro.core import workloads
from repro.core.predictor import ProfetConfig
from repro.serve import (BackgroundServer, LatencyService, ShardPlane,
                         replay, synthetic_requests)

TARGET_SCALING = 2.5
P99_RATIO_FLOOR = 3.0
N_WORKERS = 4


def _fit_oracle(smoke: bool) -> api.LatencyOracle:
    # float64-only members: worker processes stay jax-free and the
    # bit-identity gate is exact. Six devices = 30 pair groups to shard.
    devices = ("T4", "V100", "K80", "M60", "A10", "P100")
    if smoke:
        ds = workloads.generate(devices=devices,
                                models=("LeNet5", "AlexNet", "ResNet18"))
        cfg = ProfetConfig(members=("linear", "forest"), n_trees=30,
                           seed=0)
    else:
        ds = workloads.generate(devices=devices,
                                models=("LeNet5", "AlexNet", "ResNet18",
                                        "VGG11", "ResNet50",
                                        "MobileNetV2"))
        cfg = ProfetConfig(members=("linear", "forest"), n_trees=60,
                           seed=0)
    return api.LatencyOracle.fit(ds, cfg)


def _wave_inputs(oracle: api.LatencyOracle, n_rows: int):
    """One big wave with rows spread evenly over every pair group."""
    bank = oracle.bank
    rng = np.random.default_rng(0)
    cases = oracle.dataset.cases
    gids = np.arange(n_rows, dtype=np.int64) % len(bank.pairs)
    feats = {a: oracle.feature_matrix(a, cases)
             for a in {p[0] for p in bank.pairs}}
    rows = rng.integers(0, len(cases), n_rows)
    X = np.stack([feats[bank.pairs[g][0]][r] for g, r in zip(gids, rows)])
    return X, gids


def _row_plane(oracle: api.LatencyOracle, smoke: bool) -> dict:
    n_rows = 6000 if smoke else 12000
    X, gids = _wave_inputs(oracle, n_rows)
    bank = oracle.bank
    reps = 7 if smoke else 5

    want = bank.execute(X, gids)           # warm the single-worker path
    singles = []
    for _ in range(reps):
        t0 = time.perf_counter()
        bank.execute(X, gids)
        singles.append(time.perf_counter() - t0)
    t_single = min(singles)

    with ShardPlane(workers=N_WORKERS, mode="spawn") as plane:
        sharded = plane.load(bank)
        got = sharded.execute(X, gids)     # warm workers (first touch)
        np.testing.assert_array_equal(got, want)   # gate 2: bit-identity
        walls, parents, busies = [], [], []
        for _ in range(reps):
            got = sharded.execute(X, gids)
            lw = sharded.last_wave
            busy = list(lw["busy_s"].values())
            walls.append(lw["wall_s"])
            parents.append(max(lw["wall_s"] - sum(busy), 0.0))
            busies.append(max(busy))
        np.testing.assert_array_equal(got, want)
        assert plane.slice_errors == 0 and plane.fallback_rows == 0
    # each component is a deterministic cost plus scheduler noise that
    # only ever inflates it, so take the best rep of each independently
    best = {"wall_s": min(walls), "parent_s": min(parents),
            "busy_s": [min(busies)],
            "critical_s": min(parents) + min(busies)}
    scaling = t_single / best["critical_s"]
    return {"rows": n_rows, "pairs": len(bank.pairs),
            "workers": N_WORKERS, "mode": "spawn",
            "cores": os.cpu_count(),
            "single_ms": 1e3 * t_single,
            "sharded_wall_ms": 1e3 * best["wall_s"],
            "parent_ms": 1e3 * best["parent_s"],
            "max_busy_ms": 1e3 * max(best["busy_s"]),
            "critical_path_ms": 1e3 * best["critical_s"],
            "scaling": scaling, "bit_identical": True}


def _replay_tier(oracle: api.LatencyOracle, smoke: bool) -> dict:
    n_requests = 12_000 if smoke else 100_000
    base = synthetic_requests(oracle, n=500, seed=0)
    reqs = (base * (n_requests // len(base) + 1))[:n_requests]

    def drive(plane):
        svc = LatencyService(oracle, max_wave=64, shard_plane=plane)
        bg = BackgroundServer(svc, host="127.0.0.1", port=0).start()
        try:
            return replay(bg.host, bg.port, reqs, clients=8)
        finally:
            bg.stop()

    clean = drive(None)                    # single-worker baseline
    with ShardPlane(workers=N_WORKERS, mode="spawn") as plane:
        sharded = drive(plane)
        summary = plane.summary()
    # "lost" counts everything that did not come back 200 — a typed
    # rejection is still a request the sharded tier failed to serve
    lost = sharded["n"] - sharded["ok"]
    ratio = sharded["client_p99_ms"] / clean["client_p99_ms"]
    return {"n_requests": n_requests,
            "clean_p99_ms": clean["client_p99_ms"],
            "clean_rps": clean["requests_per_s"],
            "sharded_p99_ms": sharded["client_p99_ms"],
            "sharded_rps": sharded["requests_per_s"],
            "p99_ratio": ratio, "lost": lost,
            "slice_errors": summary["slice_errors"],
            "fallback_rows": summary["fallback_rows"]}


def run(smoke: bool = False) -> dict:
    oracle = _fit_oracle(smoke)
    oracle.warmup(max_rows=512)
    rp = _row_plane(oracle, smoke)
    rt = _replay_tier(oracle, smoke)
    out = {"smoke": smoke, "row_plane": rp, "replay": rt,
           "target_scaling": TARGET_SCALING,
           "p99_ratio_floor": P99_RATIO_FLOOR}
    from benchmarks import common
    common.save("shard", out)
    return out


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    smoke = "--smoke" in argv
    t0 = time.perf_counter()
    r = run(smoke=smoke)
    wall = time.perf_counter() - t0
    rp, rt = r["row_plane"], r["replay"]
    print(f"shard: {rp['rows']} rows over {rp['pairs']} groups x "
          f"{rp['workers']} spawn workers ({rp['cores']} cores) -> "
          f"single {rp['single_ms']:.1f} ms  "
          f"critical path {rp['critical_path_ms']:.1f} ms "
          f"(parent {rp['parent_ms']:.1f} + busy {rp['max_busy_ms']:.1f})  "
          f"scaling {rp['scaling']:.2f}x (target >= {TARGET_SCALING}x)")
    print(f"       replay {rt['n_requests']} requests: "
          f"clean p99 {rt['clean_p99_ms']:.2f} ms  "
          f"sharded p99 {rt['sharded_p99_ms']:.2f} ms "
          f"(ratio {rt['p99_ratio']:.2f} <= {P99_RATIO_FLOOR})  "
          f"lost {rt['lost']}")
    ok = (rp["scaling"] >= TARGET_SCALING and rp["bit_identical"]
          and rt["lost"] == 0 and rt["p99_ratio"] <= P99_RATIO_FLOOR)
    from benchmarks import common
    common.save_bench(
        "shard", speedup=rp["scaling"], floor=TARGET_SCALING, wall_s=wall,
        passed=ok, smoke=smoke,
        extra={"mode": rp["mode"], "workers": rp["workers"],
               "cores": rp["cores"], "bit_identical": rp["bit_identical"],
               "replay_requests": rt["n_requests"],
               "replay_lost": rt["lost"],
               "replay_p99_ratio": rt["p99_ratio"],
               "p99_ratio_floor": P99_RATIO_FLOOR})
    if not ok:
        print("FAIL: sharded wave execution under its floors")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
