"""Live-calibration recovery gate: drift-injected replay must recover its
accuracy within a bounded number of traffic rounds, at zero added cost on
the serving hot path.

One (anchor, target) pair's "real" latency drifts by DRIFT_FACTOR while
synthetic clients replay mixed traffic against the HTTP transport and
report their measured latencies through the columnar ``POST /measure``
firehose. The calibration control loop (stepped deterministically between
rounds) must detect the drift, refit the pair in the background, pass the
shadow canary, and promote the candidate — pulling the pair's live rolling
MAPE from the drifted plateau back under the trigger threshold.

Acceptance floors:
  - accuracy recovery >= TARGET_RECOVERY x (drifted-plateau MAPE over
    post-promotion MAPE on the injected pair);
  - recovery within MAX_ROUNDS drifted traffic rounds;
  - promotion happened exactly once, with zero rollbacks and zero shadow
    errors;
  - client p99 with the calibrator attached stays within P99_SLACK of the
    clean pre-drift round — calibration must never tax the serving path.

    PYTHONPATH=src python -m benchmarks.bench_calibrate           # full
    PYTHONPATH=src python -m benchmarks.bench_calibrate --smoke   # CI gate
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro import api
from repro.calibrate import CalibrationConfig, Calibrator
from repro.core import workloads
from repro.core.predictor import ProfetConfig
from repro.serve import (BackgroundServer, LatencyService, replay,
                         synthetic_requests)

TARGET_RECOVERY = 3.0     # drifted MAPE / recovered MAPE on the pair
MAX_ROUNDS = 8            # drifted rounds allowed until recovery
P99_SLACK = 3.0           # calibrated p99 <= slack x clean p99 (+1 ms)
DRIFT_FACTOR = 1.6
TRIGGER_MAPE = 10.0
N_CLIENTS = 4


def _fit_oracle(smoke: bool) -> api.LatencyOracle:
    if smoke:
        ds = workloads.generate(devices=("T4", "V100"),
                                models=("LeNet5", "AlexNet", "ResNet18"))
        cfg = ProfetConfig(members=("linear", "forest"), n_trees=30, seed=0)
    else:
        ds = workloads.generate(
            devices=("T4", "V100", "K80", "M60"),
            models=("LeNet5", "AlexNet", "ResNet18", "VGG11", "ResNet50",
                    "MobileNetV2"))
        cfg = ProfetConfig(dnn_epochs=40, n_trees=60, seed=0)
    return api.LatencyOracle.fit(ds, config=cfg)


def run(smoke: bool = False) -> dict:
    oracle = _fit_oracle(smoke)
    n_requests = 120 if smoke else 240
    svc = LatencyService(oracle, max_wave=32)
    cal = Calibrator(svc, CalibrationConfig(
        trigger_mape=TRIGGER_MAPE, min_obs=8, min_refit_obs=6,
        drift_confirm_obs=24, cooldown_scored=16, canary_min_obs=4,
        confirm_obs=16))
    bg = BackgroundServer(svc, calibrator=cal).start()
    try:
        ds = oracle.dataset
        pair = oracle.pairs()[0]
        label = f"{pair[0]}->{pair[1]}"
        rng = np.random.default_rng(0)
        drifting = {"on": False}

        def measure_fn(req, res):
            case = (res["workload"]["model"], res["workload"]["batch"],
                    res["workload"]["pix"])
            if case not in ds.measurements.get(res["target"], {}):
                return None
            truth = ds.latency(res["target"], case)
            if drifting["on"] and (res["anchor"], res["target"]) == pair:
                truth *= DRIFT_FACTOR
            return truth * (1.0 + rng.normal(0.0, 0.01))

        def round_(seed):
            reqs = synthetic_requests(oracle, n=n_requests, seed=seed)
            rep = replay(bg.host, bg.port, reqs, clients=N_CLIENTS,
                         measure_fn=measure_fn)
            assert rep["ok"] == rep["n"] and not rep["errors"]
            cal.step()                     # deterministic control step
            return rep

        # clean pre-drift round: baseline MAPE and baseline p99 (the
        # calibrator is attached and ingesting — its cost is in this
        # number too, which is exactly the point)
        clean = round_(0)
        clean_mape = cal.detector.mape(pair)
        assert not cal.detector.drifted_pairs()

        drifting["on"] = True
        drifted_plateau = 0.0
        recovery_round = None
        final = clean
        for rnd in range(1, MAX_ROUNDS + 1):
            final = round_(rnd)
            m = cal.detector.mape(pair)
            if cal.stats.promotions == 0:
                drifted_plateau = max(drifted_plateau,
                                      0.0 if np.isnan(m) else m)
            if (cal.stats.promotions and recovery_round is None
                    and m < TRIGGER_MAPE):
                recovery_round = rnd
                break
        recovered_mape = cal.detector.mape(pair)

        recovery = (drifted_plateau / recovered_mape
                    if recovered_mape > 0 else float("inf"))
        p99_ratio = final["client_p99_ms"] / max(clean["client_p99_ms"],
                                                 1e-9)
        p99_ok = final["client_p99_ms"] <= \
            P99_SLACK * clean["client_p99_ms"] + 1.0
        s = cal.stats
        out = {"smoke": smoke, "pair": label, "drift_factor": DRIFT_FACTOR,
               "trigger_mape": TRIGGER_MAPE,
               "clean_mape": clean_mape,
               "drifted_plateau_mape": drifted_plateau,
               "recovered_mape": recovered_mape,
               "recovery": recovery, "target_recovery": TARGET_RECOVERY,
               "recovery_round": recovery_round, "max_rounds": MAX_ROUNDS,
               "clean_p99_ms": clean["client_p99_ms"],
               "final_p99_ms": final["client_p99_ms"],
               "p99_ratio": p99_ratio, "p99_ok": p99_ok,
               "epoch": svc.epoch,
               "drift_events": s.drift_events, "refits": s.refits,
               "canary_pass": s.canary_pass, "canary_fail": s.canary_fail,
               "promotions": s.promotions, "rollbacks": s.rollbacks,
               "shadow_waves": s.shadow_waves,
               "shadow_errors": s.shadow_errors}
        from benchmarks import common
        common.save("calibrate", {**out, "events": list(s.events)})
        return out
    finally:
        cal.stop()
        bg.stop()


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    smoke = "--smoke" in argv
    t0 = time.perf_counter()
    r = run(smoke=smoke)
    wall = time.perf_counter() - t0
    print(f"calibrate: {r['pair']} drifted x{r['drift_factor']}  "
          f"MAPE {r['clean_mape']:.1f} -> {r['drifted_plateau_mape']:.1f} "
          f"-> {r['recovered_mape']:.1f}  "
          f"recovery {r['recovery']:.1f}x (target >= "
          f"{r['target_recovery']:.0f}x) in round "
          f"{r['recovery_round']}/{r['max_rounds']}")
    print(f"  loop: {r['drift_events']} drift events  {r['refits']} refits  "
          f"canary {r['canary_pass']}p/{r['canary_fail']}f  "
          f"{r['promotions']} promotions  {r['rollbacks']} rollbacks  "
          f"epoch {r['epoch']}")
    print(f"  hot path: clean p99 {r['clean_p99_ms']:.2f} ms  "
          f"calibrated p99 {r['final_p99_ms']:.2f} ms  "
          f"(ratio {r['p99_ratio']:.2f}, slack {P99_SLACK:.1f}x)")
    ok = (r["recovery"] >= r["target_recovery"]
          and r["recovery_round"] is not None
          and r["promotions"] == 1 and r["rollbacks"] == 0
          and r["shadow_errors"] == 0 and r["p99_ok"])
    from benchmarks import common
    common.save_bench("calibrate", speedup=r["recovery"],
                      floor=r["target_recovery"], wall_s=wall, passed=ok,
                      smoke=smoke,
                      extra={"recovery_round": r["recovery_round"],
                             "promotions": r["promotions"],
                             "rollbacks": r["rollbacks"],
                             "p99_ratio": r["p99_ratio"]})
    if not ok:
        print("FAIL: live calibration did not recover the drifted pair "
              "cleanly (see record)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
