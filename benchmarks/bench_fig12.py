"""Fig 12: order-1 vs order-2 polynomial knob model, per instance type."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import workloads
from repro.core.devices import PAPER_DEVICES
from repro.core.scaling import PolyScaler


def run() -> dict:
    ds = common.dataset().subset(PAPER_DEVICES)
    train, test = common.split()

    out = {}
    for order in (1, 2):
        per_dev = {}
        for dev in PAPER_DEVICES:
            kb, lat, grp = [], [], []
            for (m, b, p) in train:
                kb.append(b)
                lat.append(ds.latency(dev, (m, b, p)))
                grp.append(f"{m}|{p}")
            sc = PolyScaler(order=order, min_knob=16, max_knob=256).fit(
                np.array(kb, float), np.array(lat), np.array(grp))
            have = set(ds.cases)
            truths, preds = [], []
            for (m, b, p) in test:
                if b in (16, 256) or (m, 16, p) not in have \
                        or (m, 256, p) not in have:
                    continue
                lo = ds.latency(dev, (m, 16, p))
                hi = ds.latency(dev, (m, 256, p))
                truths.append(ds.latency(dev, (m, b, p)))
                preds.append(float(sc.predict(b, lo, hi)))
            per_dev[dev] = common.metrics(np.array(truths), np.array(preds))
        out[f"order{order}"] = per_dev

    common.save("fig12", out)
    avg = {o: np.mean([m["mape"] for m in per.values()])
           for o, per in out.items()}
    return {"order1_avg_mape": avg["order1"], "order2_avg_mape": avg["order2"]}
