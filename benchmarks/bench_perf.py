"""§Perf before/after: compares results/dryrun_baseline (pre-optimization)
against results/dryrun (optimized) per cell — the mechanized version of the
EXPERIMENTS.md §Perf summary table."""
from __future__ import annotations

import json
import pathlib

from benchmarks import common

BASE = pathlib.Path("results/dryrun_baseline")
OPT = pathlib.Path("results/dryrun")


def _load(d):
    out = {}
    for f in sorted(d.glob("*_single.json")):
        r = json.loads(f.read_text())
        if r.get("status") == "ok":
            out[(r["arch"], r["shape"])] = r["roofline"]
    return out


def run() -> dict:
    if not BASE.exists():
        print("  (no baseline snapshot — run the dry-run twice around the "
              "perf changes)")
        return {"cells": 0}
    base, opt = _load(BASE), _load(OPT)
    rows, speedups = [], {}
    for key in sorted(opt):
        if key not in base:
            continue
        b, o = base[key], opt[key]
        bb = max(b["t_compute_s"], b["t_memory_s"], b["t_collective_s"])
        ob = max(o["t_compute_s"], o["t_memory_s"], o["t_collective_s"])
        sp = bb / ob if ob else float("inf")
        speedups["/".join(key)] = sp
        rows.append(["/".join(key), f"{bb:.3f}", f"{ob:.3f}", f"{sp:.2f}x",
                     f"{b['roofline_fraction']:.4f}",
                     f"{o['roofline_fraction']:.4f}"])
    rows.sort(key=lambda r: -float(r[3][:-1]))
    print(common.fmt_table(rows, ["cell", "base_bound_s", "opt_bound_s",
                                  "speedup", "base_roof", "opt_roof"]))
    common.save("perf", {"speedups": speedups})
    top = sorted(speedups.items(), key=lambda kv: -kv[1])[:3]
    return {"cells": len(rows),
            **{f"top_{i}_{k}": v for i, (k, v) in enumerate(top)}}
