"""End-to-end ``Profet.fit`` speedup: the vectorized training hot path.

Baseline = the pre-PR fit, replayed by
``repro.core.reference.fit_profet_reference``: one recursive per-node CART
forest per (anchor, target) pair (a fresh ``argsort`` per node per feature,
the seed's row-duplication bootstrap) and one sequential host-loop DNN per
pair with a FRESH jit trace each fit (including the seed's dropped-tail
minibatch loop) — so both the cost AND the accuracy of what the code
actually did before this PR are what the new path is held against.
Vectorized = today's ``Profet.fit``: per anchor one shared feature matrix,
one level-synchronous packed-forest pass per target, and all targets' DNN
heads trained in a single vmapped ``lax.scan`` call.

The vectorized path is timed WARM (its module-level jit cache populated by
an untimed first fit — the production refit regime the ROADMAP targets);
the baseline retraces every fit by construction, so warming cannot help it.

Accuracy parity is reported alongside: both fitted predictors score
phase-1 cross-instance MAPE on a held-out case split (the bench_tab2
protocol); the floor fails if they diverge beyond noise.

    PYTHONPATH=src python -m benchmarks.bench_fit           # full paper grid
    PYTHONPATH=src python -m benchmarks.bench_fit --smoke   # CI gate
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core import reference, workloads
from repro.core.ensemble import mape
from repro.core.predictor import Profet, ProfetConfig

TARGET_SPEEDUP = 5.0     # full-grid acceptance floor
SMOKE_FLOOR = 2.0        # conservative CI floor (cold machines, small grid)
MAPE_PARITY_PTS = 3.0    # regression budget: MAPE_new - MAPE_ref, pct points
                         # (one-sided — beating the seed path never fails)


def _setup(smoke: bool):
    if smoke:
        ds = workloads.generate(devices=("T4", "V100"),
                                models=("LeNet5", "AlexNet", "ResNet18"))
        cfg = ProfetConfig(dnn_epochs=40, n_trees=30, seed=0)
    else:
        ds = workloads.generate()    # the paper's full device/model grid
        cfg = ProfetConfig(seed=0)   # default epochs/trees — the real fit
    train, test = workloads.split_cases(ds.cases, test_frac=0.25, seed=0)
    return ds, cfg, train, test


def _cross_mape(profet: Profet, ds, test) -> float:
    """Mean phase-1 MAPE over every trained pair on the held-out cases."""
    scores = []
    X_by_anchor = {}
    for (ga, gt) in sorted(profet.cross):
        if ga not in X_by_anchor:
            X_by_anchor[ga] = profet.feature_matrix(
                [ds.profile(ga, c) for c in test], test)
        y_true = np.array([ds.latency(gt, c) for c in test])
        scores.append(mape(y_true, profet.predict_cross_matrix(
            ga, gt, X_by_anchor[ga])))
    return float(np.mean(scores))


def run(smoke: bool = False) -> dict:
    ds, cfg, train, test = _setup(smoke)

    # vectorized path: one untimed warmup fit populates the jit cache
    Profet(cfg).fit(ds, train)
    t0 = time.perf_counter()
    new = Profet(cfg).fit(ds, train)
    t_new = time.perf_counter() - t0

    t0 = time.perf_counter()
    ref = reference.fit_profet_reference(ds, cfg, train)
    t_ref = time.perf_counter() - t0

    mape_new = _cross_mape(new, ds, test)
    mape_ref = _cross_mape(ref, ds, test)
    speedup = t_ref / t_new
    floor = SMOKE_FLOOR if smoke else TARGET_SPEEDUP
    out = {"smoke": smoke, "n_pairs": len(new.cross),
           "n_train_cases": len(train),
           "ref_s": t_ref, "new_s": t_new, "speedup": speedup,
           "floor": floor, "mape_new": mape_new, "mape_ref": mape_ref,
           "mape_delta_pts": mape_new - mape_ref,
           "mape_parity_pts": MAPE_PARITY_PTS}
    from benchmarks import common
    common.save("fit", out)
    return out


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    smoke = "--smoke" in argv
    t0 = time.perf_counter()
    r = run(smoke=smoke)
    wall = time.perf_counter() - t0
    print(f"Profet.fit: {r['n_pairs']} pairs x {r['n_train_cases']} cases  "
          f"reference {r['ref_s']:.1f} s  vectorized {r['new_s']:.1f} s  "
          f"speedup {r['speedup']:.1f}x (floor >= {r['floor']:.0f}x)")
    print(f"  held-out cross MAPE: vectorized {r['mape_new']:.2f}%  "
          f"reference {r['mape_ref']:.2f}%  "
          f"delta {r['mape_delta_pts']:+.2f} pts "
          f"(fails above +{r['mape_parity_pts']:.0f}; better never fails)")
    ok = (r["speedup"] >= r["floor"]
          and r["mape_delta_pts"] <= r["mape_parity_pts"])
    from benchmarks import common
    common.save_bench("fit", speedup=r["speedup"], floor=r["floor"],
                      wall_s=wall, passed=ok, smoke=smoke,
                      extra={"mape_delta_pts": r["mape_delta_pts"]})
    if r["speedup"] < r["floor"]:
        print("FAIL: vectorized fit under the speedup floor")
        return 1
    if r["mape_delta_pts"] > r["mape_parity_pts"]:
        print("FAIL: vectorized path LOST accuracy vs the pre-PR reference")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
