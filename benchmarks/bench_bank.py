"""Stacked ModelBank wave execution vs the per-group executor path.

One full-catalog mixed wave — every trained (anchor, target) pair, cross +
two-phase + measured requests shuffled together — executed twice from the
same prebuilt plans:

  baseline = the per-group path (one fused ``MedianEnsemble.predict`` per
  pair: O(pairs) Python dispatches, O(pairs) forest traversals, O(pairs)
  separately padded MLP applies);
  stacked  = ``oracle.execute`` through the ModelBank (ONE grouped forest
  launch + ONE stacked MLP apply + row-stable linear/median for the whole
  wave, ``fused_calls == 1``).

Equality is asserted on every run: stacked answers must match the
per-group path element-wise — bit-for-bit for the float64 members (linear,
forest, phase-2 interpolation, checked member-wise across every pair), and
to float32 precision for the DNN member. Acceptance floor: >= 3x.

    PYTHONPATH=src python -m benchmarks.bench_bank           # full
    PYTHONPATH=src python -m benchmarks.bench_bank --smoke   # CI gate
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro import api
from repro.api import executor
from repro.core import workloads
from repro.core.predictor import ProfetConfig
from repro.core.regressors import LinearRegressor
from repro.kernels import forest_eval
from repro.serve import synthetic_requests

TARGET_SPEEDUP = 3.0
N_REQUESTS = 600


def _fit_oracle(smoke: bool) -> api.LatencyOracle:
    # the win scales with the pair count (the per-group path pays O(pairs)
    # dispatches), so both tiers sweep SIX devices = 30 pairs; smoke keeps
    # the fit cheap with fewer models and a token DNN
    if smoke:
        ds = workloads.generate(
            devices=("T4", "V100", "K80", "M60", "A10", "P100"),
            models=("LeNet5", "AlexNet", "ResNet18"))
        cfg = ProfetConfig(dnn_epochs=5, n_trees=30, seed=0)
    else:
        ds = workloads.generate(
            devices=("T4", "V100", "K80", "M60", "A10", "P100"),
            models=("LeNet5", "AlexNet", "ResNet18", "VGG11", "ResNet50",
                    "MobileNetV2"))
        cfg = ProfetConfig(dnn_epochs=40, n_trees=60, seed=0)
    return api.LatencyOracle.fit(ds, cfg)


def _assert_float64_members_exact(oracle: api.LatencyOracle) -> None:
    """Bank linear + forest stacks vs each pair's own fitted members —
    must agree bit-for-bit on shared rows."""
    bank = oracle.bank
    f = bank.forest
    for pair in oracle.pairs():
        anchor, _ = pair
        X = oracle.feature_matrix(anchor, oracle.dataset.cases[:8])
        gids = np.full(len(X), bank.gid[pair])
        ens = oracle.ensemble(*pair)
        np.testing.assert_array_equal(
            LinearRegressor.apply(LinearRegressor._design(X),
                                  bank.lin_coef[gids]),
            ens.models["linear"].predict(X))
        np.testing.assert_array_equal(
            forest_eval.predict_grouped(
                X, gids, f["feat"], f["thr"], f["left"], f["right"],
                f["value"], depth=f["depth"], backend="numpy"),
            ens.models["forest"].predict(X))


def _timed(fn, *args, reps: int):
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    return ts


def run(smoke: bool = False) -> dict:
    oracle = _fit_oracle(smoke)
    oracle.warmup(max_rows=N_REQUESTS)     # compiles out of the timed loop
    reqs = synthetic_requests(oracle, n=N_REQUESTS, seed=0)
    plans = [oracle.plan(r) for r in reqs]

    def per_group():
        return executor.execute_plans(oracle.profet, plans, epoch="bench",
                                      bank=None)

    def stacked():
        return oracle.execute(plans)

    banked, legacy = stacked(), per_group()   # warm both + equality audit
    assert banked.banked and banked.fused_calls == 1, banked.fused_calls
    assert not legacy.banked and legacy.fused_calls == len(
        {(p.anchor, p.target) for p in plans
         if p.mode != api.MODE_MEASURED})
    pairs_hit = {(r.anchor, r.target) for r in banked
                 if r.anchor != r.target}
    assert pairs_hit == set(oracle.pairs()), "wave must cover every pair"
    if "dnn" in oracle.config.members:
        np.testing.assert_allclose(banked.latencies(), legacy.latencies(),
                                   rtol=1e-5)
    else:
        np.testing.assert_array_equal(banked.latencies(),
                                      legacy.latencies())
    _assert_float64_members_exact(oracle)

    launches0 = oracle.bank.forest_launches
    reps = 5 if smoke else 3
    t_group = min(_timed(per_group, reps=reps))
    t_stack = min(_timed(stacked, reps=reps))
    assert oracle.bank.forest_launches == launches0 + reps
    speedup = t_group / t_stack
    out = {"smoke": smoke, "n_requests": len(reqs),
           "pairs": len(oracle.pairs()),
           "per_group_fused_calls": legacy.fused_calls,
           "stacked_fused_calls": banked.fused_calls,
           "rows": banked.rows, "modes": dict(banked.mode_counts),
           "per_group_ms": 1e3 * t_group, "stacked_ms": 1e3 * t_stack,
           "speedup": speedup, "target_speedup": TARGET_SPEEDUP}
    from benchmarks import common
    common.save("bank", out)
    return {"n_requests": len(reqs), "pairs": len(oracle.pairs()),
            "per_group_ms": out["per_group_ms"],
            "stacked_ms": out["stacked_ms"], "speedup": speedup}


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    smoke = "--smoke" in argv
    t0 = time.perf_counter()
    r = run(smoke=smoke)
    wall = time.perf_counter() - t0
    print(f"bank: {r['n_requests']} mixed requests over {r['pairs']} pairs "
          f"-> per-group {r['per_group_ms']:.1f} ms  "
          f"stacked {r['stacked_ms']:.1f} ms  "
          f"speedup {r['speedup']:.1f}x (target >= {TARGET_SPEEDUP:.0f}x)")
    from benchmarks import common
    ok = r["speedup"] >= TARGET_SPEEDUP
    common.save_bench("bank", speedup=r["speedup"], floor=TARGET_SPEEDUP,
                      wall_s=wall, passed=ok, smoke=smoke,
                      extra={"pairs": r["pairs"],
                             "stacked_fused_calls": 1})
    if not ok:
        print("FAIL: stacked wave execution under the speedup floor")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
