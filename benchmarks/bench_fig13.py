"""Fig 13: prediction-accuracy improvement from feature clustering.

(a) Models containing UNIQUE operations (Relu6/depthwise, LRN, branch
    concats, SSM-style op drift) are held out of training entirely; their
    profiles then contain op names the model never saw — clustering routes
    them to near-name clusters instead of dropping them.
(b) Models with only COMMON features (ResNet/VGG variants) must not regress.

Beyond-paper: an ``ssm_ops`` column simulates an attention-free workload
whose profile op names drift (Conv2D->DepthwiseConv2dNativeV2-style renames),
the TPU-side scenario where XLA opcode names shift across compiler versions.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro import api
from repro.core import workloads
from repro.core.devices import PAPER_DEVICES
from repro.core.ensemble import mape
from repro.core.predictor import ProfetConfig

# models whose profiles contain op names unique to them in OUR zoo:
# MobileNetV2 (Relu6*, DepthwiseConv2dNative*), AlexNet (LRN*), LeNet5
# (Tanh*). InceptionV3 is NOT unique here — ConcatV2 also appears in
# InceptionResNetV2 profiles.
UNIQUE_MODELS = ("MobileNetV2", "AlexNet", "LeNet5")
COMMON_MODELS = ("ResNet34", "VGG13")
ANCHOR = "T4"
TARGETS = ("V100", "K80", "M60")

_DRIFT = {"Relu": "LeakyRelu", "ReluGrad": "LeakyReluGrad",
          "FusedBatchNormV3": "FusedBatchNormV4",
          "FusedBatchNormGradV3": "FusedBatchNormGradV4"}


def _holdout_mape(ds, model_name, clustering, *, drift=False,
                  max_height=None):
    train = [c for c in ds.cases if c[0] != model_name]
    test = [c for c in ds.cases if c[0] == model_name]
    kw = {} if max_height is None else {"max_height": max_height}
    cfg = ProfetConfig(clustering=clustering, dnn_epochs=80, seed=0, **kw)
    oracle = api.LatencyOracle.fit(ds, cfg, train, anchors=(ANCHOR,),
                                   targets=TARGETS)
    errs = []
    for gt in TARGETS:
        for c in test:
            prof = dict(ds.profile(ANCHOR, c))
            if drift:
                prof = {_DRIFT.get(k, k): v for k, v in prof.items()}
            r = oracle.predict(api.PredictRequest(
                ANCHOR, gt, api.Workload.from_case(c), profile=prof,
                mode=api.MODE_CROSS))
            true = ds.latency(gt, c)
            errs.append(abs(r.latency_ms - true) / true)
    return 100.0 * float(np.mean(errs))


def run() -> dict:
    ds = common.dataset().subset(PAPER_DEVICES)

    unique = {}
    for m in UNIQUE_MODELS:
        off = _holdout_mape(ds, m, clustering=False)
        on = _holdout_mape(ds, m, clustering=True)
        unique[m] = {"mape_no_clustering": off, "mape_clustering": on,
                     "improvement_pct": 100.0 * (off - on) / off}

    commonf = {}
    for m in COMMON_MODELS:
        off = _holdout_mape(ds, m, clustering=False)
        on = _holdout_mape(ds, m, clustering=True)
        commonf[m] = {"mape_no_clustering": off, "mape_clustering": on,
                      "improvement_pct": 100.0 * (off - on) / off}

    # beyond-paper: op-name drift (unseen op strings at prediction time)
    drift = {}
    for m in ("ResNet50",):
        off = _holdout_mape(ds, m, clustering=False, drift=True)
        on = _holdout_mape(ds, m, clustering=True, drift=True)
        drift[m] = {"mape_no_clustering": off, "mape_clustering": on,
                    "improvement_pct": 100.0 * (off - on) / off}

    # the paper's own "empirical analysis" for the cut height, redone on OUR
    # op vocabulary (the paper's 6.0 was tuned to its 65 TF op names)
    height_sweep = {}
    for h in (1.5, 2.0, 3.0, 4.0, 6.0):
        height_sweep[h] = _holdout_mape(ds, "MobileNetV2", clustering=True,
                                        max_height=h)

    out = {"unique_feature_models": unique, "common_feature_models": commonf,
           "opname_drift": drift, "height_sweep_mobilenet": height_sweep}
    common.save("fig13", out)
    return {
        "unique_avg_improvement_pct": float(np.mean(
            [v["improvement_pct"] for v in unique.values()])),
        "common_avg_improvement_pct": float(np.mean(
            [v["improvement_pct"] for v in commonf.values()])),
        "drift_improvement_pct": drift["ResNet50"]["improvement_pct"],
    }
