"""Shared state for the benchmark suite: one workload grid over the full
device catalog and one fitted PROFET model, both cached on disk so the suite
is re-runnable piecemeal."""
from __future__ import annotations

import json
import pathlib
import pickle
import subprocess
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro import api
from repro.core import workloads
from repro.core.devices import PAPER_DEVICES, TPU_DEVICES, UNSEEN_DEVICES
from repro.core.ensemble import mape, r2, rmse
from repro.core.predictor import ProfetConfig

OUT = pathlib.Path("results/bench")
CACHE = pathlib.Path("results/bench/_cache")

ALL_DEVICES = PAPER_DEVICES + UNSEEN_DEVICES + TPU_DEVICES
DNN_EPOCHS = 150
SEED = 0


def dataset() -> workloads.Dataset:
    CACHE.mkdir(parents=True, exist_ok=True)
    f = CACHE / "dataset.pkl"
    if f.exists():
        with open(f, "rb") as fh:
            return pickle.load(fh)
    ds = workloads.generate(devices=ALL_DEVICES)
    with open(f, "wb") as fh:
        pickle.dump(ds, fh)
    return ds


def split() -> Tuple[list, list]:
    ds = dataset()
    return workloads.split_cases(ds.cases, test_frac=0.2, seed=SEED)


def paper_oracle() -> api.LatencyOracle:
    """Oracle fit on the paper's four instances (train split only), cached
    through the versioned artifact store (stale configs refit, not reused)."""
    cfg = ProfetConfig(dnn_epochs=DNN_EPOCHS, seed=SEED)

    def fit():
        ds = dataset().subset(PAPER_DEVICES)
        train, _ = split()
        return api.LatencyOracle.fit(ds, cfg, train)

    return api.fit_or_load(CACHE / "oracle_paper.pkl", cfg, fit_fn=fit)


def metrics(y_true, y_pred) -> Dict[str, float]:
    return {"mape": mape(y_true, y_pred), "rmse": rmse(y_true, y_pred),
            "r2": r2(y_true, y_pred)}


def save(name: str, payload: dict) -> None:
    OUT.mkdir(parents=True, exist_ok=True)
    payload = dict(payload, _benchmark=name, _timestamp=time.time())
    (OUT / f"{name}.json").write_text(json.dumps(payload, indent=1,
                                                 default=float))


def git_sha() -> str:
    try:
        return subprocess.run(["git", "rev-parse", "--short=12", "HEAD"],
                              capture_output=True, text=True, timeout=10,
                              check=True).stdout.strip()
    except Exception:
        return "unknown"


def save_bench(name: str, *, speedup: float, floor: float, wall_s: float,
               passed: bool, smoke: bool = False,
               extra: Optional[dict] = None) -> pathlib.Path:
    """Machine-readable gate record: every floor-gated ``bench_*`` run
    writes ``results/bench/BENCH_<name>.json`` (speedup, floor, wall time,
    git SHA) so CI can upload them as the perf-trajectory artifact and
    ``scripts/bench_report.py`` can print the table."""
    OUT.mkdir(parents=True, exist_ok=True)
    rec = {"benchmark": name, "speedup": float(speedup),
           "floor": float(floor), "passed": bool(passed),
           "wall_s": float(wall_s), "smoke": bool(smoke),
           "git_sha": git_sha(), "timestamp": time.time(),
           "timestamp_iso": time.strftime("%Y-%m-%dT%H:%M:%S%z")}
    if extra:
        rec.update(extra)
    path = OUT / f"BENCH_{name}.json"
    path.write_text(json.dumps(rec, indent=1, default=float))
    return path


def fmt_table(rows, headers) -> str:
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows))
              for i, h in enumerate(headers)]
    line = lambda r: " | ".join(str(c).ljust(w) for c, w in zip(r, widths))
    sep = "-+-".join("-" * w for w in widths)
    return "\n".join([line(headers), sep] + [line(r) for r in rows])
