"""Shared state for the benchmark suite: one workload grid over the full
device catalog and one fitted PROFET model, both cached on disk so the suite
is re-runnable piecemeal."""
from __future__ import annotations

import json
import pathlib
import pickle
import time
from typing import Dict, Tuple

import numpy as np

from repro import api
from repro.core import workloads
from repro.core.devices import PAPER_DEVICES, TPU_DEVICES, UNSEEN_DEVICES
from repro.core.ensemble import mape, r2, rmse
from repro.core.predictor import ProfetConfig

OUT = pathlib.Path("results/bench")
CACHE = pathlib.Path("results/bench/_cache")

ALL_DEVICES = PAPER_DEVICES + UNSEEN_DEVICES + TPU_DEVICES
DNN_EPOCHS = 150
SEED = 0


def dataset() -> workloads.Dataset:
    CACHE.mkdir(parents=True, exist_ok=True)
    f = CACHE / "dataset.pkl"
    if f.exists():
        with open(f, "rb") as fh:
            return pickle.load(fh)
    ds = workloads.generate(devices=ALL_DEVICES)
    with open(f, "wb") as fh:
        pickle.dump(ds, fh)
    return ds


def split() -> Tuple[list, list]:
    ds = dataset()
    return workloads.split_cases(ds.cases, test_frac=0.2, seed=SEED)


def paper_oracle() -> api.LatencyOracle:
    """Oracle fit on the paper's four instances (train split only), cached
    through the versioned artifact store (stale configs refit, not reused)."""
    cfg = ProfetConfig(dnn_epochs=DNN_EPOCHS, seed=SEED)

    def fit():
        ds = dataset().subset(PAPER_DEVICES)
        train, _ = split()
        return api.LatencyOracle.fit(ds, cfg, train)

    return api.fit_or_load(CACHE / "oracle_paper.pkl", cfg, fit_fn=fit)


def metrics(y_true, y_pred) -> Dict[str, float]:
    return {"mape": mape(y_true, y_pred), "rmse": rmse(y_true, y_pred),
            "r2": r2(y_true, y_pred)}


def save(name: str, payload: dict) -> None:
    OUT.mkdir(parents=True, exist_ok=True)
    payload = dict(payload, _benchmark=name, _timestamp=time.time())
    (OUT / f"{name}.json").write_text(json.dumps(payload, indent=1,
                                                 default=float))


def fmt_table(rows, headers) -> str:
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows))
              for i, h in enumerate(headers)]
    line = lambda r: " | ".join(str(c).ljust(w) for c, w in zip(r, widths))
    sep = "-+-".join("-" * w for w in widths)
    return "\n".join([line(headers), sep] + [line(r) for r in rows])
