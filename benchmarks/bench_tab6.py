"""Table VI: predicting latency on NEW GPU devices (A10, P100) from the four
existing anchors — the cloud-vendor-prepares-the-model-for-new-hardware
scenario. Beyond paper: TPU v5e as a new target chip (GPU anchor -> TPU
target), the cross-ISA case."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro import api
from repro.core.devices import PAPER_DEVICES, TPU_DEVICES, UNSEEN_DEVICES
from repro.core.ensemble import mape
from repro.core.predictor import ProfetConfig


def run() -> dict:
    ds = common.dataset()  # full catalog
    train, test = common.split()

    targets = UNSEEN_DEVICES + ("TPUv5e",)
    oracle = api.LatencyOracle.fit(
        ds, ProfetConfig(dnn_epochs=common.DNN_EPOCHS, seed=0), train,
        anchors=PAPER_DEVICES, targets=targets)

    tab6 = {}
    for gt in targets:
        tab6[gt] = {}
        for ga in PAPER_DEVICES:
            pred = oracle.predict_cases(ga, gt, test)
            true = np.array([ds.latency(gt, c) for c in test])
            tab6[gt][ga] = mape(true, pred)

    common.save("tab6", tab6)
    flat = {f"{gt}_from_{ga}": v for gt, row in tab6.items()
            for ga, v in row.items()}
    return {"a10_avg_mape": float(np.mean(list(tab6["A10"].values()))),
            "p100_avg_mape": float(np.mean(list(tab6["P100"].values()))),
            "tpuv5e_avg_mape": float(np.mean(list(tab6["TPUv5e"].values())))}
