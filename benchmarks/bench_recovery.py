"""Self-healing recovery: throughput restoration after a SIGKILLed shard
worker is auto-respawned and adopted.

PR 8/9 gate that worker death is *contained* (typed errors, parent-side
fallback, zero lost requests). This bench gates that it is also
*repaired*: with the lifecycle supervisor running, a hard worker kill
mid-replay must end with the worker re-forked, re-shipped, adopted — and
the plane back at full multi-worker throughput.

Three phases over one 4-worker spawn plane (the production local mode):

  1. **Clean** — an HTTP replay against the healthy 4-worker service:
     the baseline requests/s.
  2. **Kill** — the same replay with one worker SIGKILLed mid-stream;
     clients carry a retry policy (500/503 are retryable — a mid-wave
     death surfaces as a typed 500 whose retry answers through the
     parent fallback), so the gate is ZERO lost requests.
  3. **Recovered** — wait for the supervisor to adopt a replacement
     (bounded), then replay again: requests/s must be **>= 0.9x** the
     clean phase — adoption actually restored the plane, rather than
     leaving the shard on the single-threaded parent fallback forever.

Every answered request in every phase must match the unsharded oracle
bit-exactly (the recovery window never blends epochs or rounds).

    PYTHONPATH=src python -m benchmarks.bench_recovery           # full
    PYTHONPATH=src python -m benchmarks.bench_recovery --smoke   # CI
"""
from __future__ import annotations

import sys
import threading
import time

from benchmarks.bench_shard import _fit_oracle
from repro.serve import (BackgroundServer, LatencyService, LifecycleConfig,
                         RetryPolicy, ShardPlane, replay,
                         synthetic_requests)

THROUGHPUT_FLOOR = 0.9     # recovered rps >= 0.9x clean rps
N_WORKERS = 4
ADOPT_DEADLINE_S = 30.0

RETRY = RetryPolicy(max_attempts=5, base_s=0.02, multiplier=2.0,
                    max_backoff_s=0.5, jitter=0.0, seed=0,
                    retry_statuses=frozenset({500, 503}))


def _check_bits(rep: dict, want, phase: str) -> None:
    lost = rep["n"] - rep["ok"]
    assert lost == 0, (
        f"{phase}: {lost} lost requests ({rep['errors'][:3]})")
    for i, r in enumerate(rep["results"]):
        assert r["latency_ms"] == want[i], (
            f"{phase}: row {i} diverged from the oracle")


def run(smoke: bool = False) -> dict:
    oracle = _fit_oracle(smoke)
    oracle.warmup(max_rows=512)
    n_requests = 6000 if smoke else 20000
    base = synthetic_requests(oracle, n=500, seed=0)
    reqs = (base * (n_requests // len(base) + 1))[:n_requests]
    want_base = [r.latency_ms for r in oracle.predict_many(base)]
    want = (want_base * (n_requests // len(base) + 1))[:n_requests]

    plane = ShardPlane(workers=N_WORKERS, mode="spawn")
    svc = LatencyService(
        oracle, max_wave=64, shard_plane=plane,
        supervise=LifecycleConfig(lease_interval_s=0.05,
                                  lease_timeout_s=2.0))
    bg = BackgroundServer(svc, host="127.0.0.1", port=0).start()
    try:
        # phase 1: clean 4-worker baseline (warm, then measure)
        replay(bg.host, bg.port, reqs[:len(base)], clients=8)
        clean = replay(bg.host, bg.port, reqs, clients=8)
        _check_bits(clean, want, "clean")

        # phase 2: SIGKILL one worker mid-replay; retries absorb the
        # typed mid-wave 500s -> zero lost
        victim = plane.workers[1]
        killer = threading.Timer(
            min(0.2, clean["wall_s"] / 4), victim.kill)
        killer.start()
        killed = replay(bg.host, bg.port, reqs, clients=8, retry=RETRY)
        killer.join()
        _check_bits(killed, want, "killed")

        # phase 3: bounded wait for adoption, then the restored rate
        deadline = time.monotonic() + ADOPT_DEADLINE_S
        while plane.adoptions < 1 and time.monotonic() < deadline:
            time.sleep(0.05)
        adopted = plane.adoptions >= 1 and plane.alive_workers() == N_WORKERS
        recovered = replay(bg.host, bg.port, reqs, clients=8)
        _check_bits(recovered, want, "recovered")
        lifecycle = plane.summary()["lifecycle"]
    finally:
        bg.stop()
        plane.close()

    ratio = recovered["requests_per_s"] / clean["requests_per_s"]
    out = {"smoke": smoke, "n_requests": n_requests,
           "workers": N_WORKERS,
           "clean_rps": clean["requests_per_s"],
           "killed_rps": killed["requests_per_s"],
           "recovered_rps": recovered["requests_per_s"],
           "throughput_ratio": ratio,
           "throughput_floor": THROUGHPUT_FLOOR,
           "adopted": adopted,
           "respawns": lifecycle["respawns"],
           "lost": 0, "bit_identical": True}
    from benchmarks import common
    common.save("recovery", out)
    return out


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    smoke = "--smoke" in argv
    t0 = time.perf_counter()
    r = run(smoke=smoke)
    wall = time.perf_counter() - t0
    print(f"recovery: {r['n_requests']} requests x {r['workers']} spawn "
          f"workers -> clean {r['clean_rps']:.0f} req/s  "
          f"killed {r['killed_rps']:.0f} req/s (0 lost)  "
          f"recovered {r['recovered_rps']:.0f} req/s "
          f"(ratio {r['throughput_ratio']:.2f} >= {THROUGHPUT_FLOOR})  "
          f"respawns {r['respawns']}")
    ok = (r["adopted"] and r["lost"] == 0 and r["bit_identical"]
          and r["throughput_ratio"] >= THROUGHPUT_FLOOR)
    from benchmarks import common
    common.save_bench(
        "recovery", speedup=r["throughput_ratio"],
        floor=THROUGHPUT_FLOOR, wall_s=wall, passed=ok, smoke=smoke,
        extra={"workers": r["workers"], "clean_rps": r["clean_rps"],
               "killed_rps": r["killed_rps"],
               "recovered_rps": r["recovered_rps"],
               "adopted": r["adopted"], "respawns": r["respawns"],
               "lost": r["lost"], "bit_identical": r["bit_identical"]})
    if not ok:
        print("FAIL: post-recovery throughput under its floor "
              "(or adoption/zero-lost gate broken)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
