"""Serving scheduler benchmark (REAL measurements on the CPU device, smoke
configs): continuous (inflight) batching vs wave-aligned static batching on
a mixed-length request trace — the beyond-paper serving deliverable."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import common
from repro.configs import base as CB
from repro.models import model as M
from repro.serve.engine import Engine

ARCHS = ("llama3_2_1b", "mamba2_130m")


def _trace(rng, n=10):
    """Mixed prompt/output lengths — the case wave scheduling handles worst."""
    out = []
    for _ in range(n):
        out.append((rng.integers(2, 24, endpoint=True),
                    rng.integers(2, 10, endpoint=True)))
    return out


def _run(cfg, params, mode, trace):
    eng = Engine(cfg, params, batch_slots=4, max_len=96, mode=mode)
    rng = np.random.default_rng(0)
    reqs = []
    for plen, n_new in trace:
        prompt = rng.integers(1, 200, size=int(plen)).tolist()
        reqs.append(eng.submit(prompt, max_new_tokens=int(n_new)))
    t0 = time.time()
    eng.run()
    wall = time.time() - t0
    lat = [r.t_finish - r.t_submit for r in reqs]
    return {"wall_s": wall,
            "tokens_per_s": eng.stats.generated_tokens / wall,
            "decode_steps": eng.stats.decode_steps,
            "p50_latency_s": float(np.median(lat)),
            "p99_latency_s": float(np.quantile(lat, 0.99))}


def run() -> dict:
    rng = np.random.default_rng(7)
    trace = _trace(rng)
    out = {}
    for arch in ARCHS:
        cfg = CB.get_config(arch, smoke=True)
        params, _ = M.init(jax.random.PRNGKey(0), cfg)
        # warm the jit once so compilation doesn't skew either mode
        warm = Engine(cfg, params, batch_slots=4, max_len=96)
        warm.submit([1, 2], max_new_tokens=2)
        warm.run()
        out[arch] = {m: _run(cfg, params, m, trace)
                     for m in ("continuous", "wave")}
    common.save("serving", out)
    summary = {}
    for arch, modes in out.items():
        speed = (modes["continuous"]["tokens_per_s"]
                 / modes["wave"]["tokens_per_s"])
        steps = (modes["wave"]["decode_steps"]
                 / max(modes["continuous"]["decode_steps"], 1))
        summary[f"{arch}_throughput_gain"] = speed
        summary[f"{arch}_step_reduction"] = steps
    return summary
