"""Fig 11: batch-size latency prediction for b in {32, 64, 128} with (a) TRUE
min/max latencies and (b) min/max PREDICTED by the cross-instance model."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro import api
from repro.core.devices import PAPER_DEVICES
from repro.core.ensemble import mape


def run() -> dict:
    ds = common.dataset().subset(PAPER_DEVICES)
    train, test = common.split()
    oracle = common.paper_oracle()

    mid_batches = (32, 64, 128)
    true_mode = {b: [] for b in mid_batches}
    pred_mode = {b: [] for b in mid_batches}

    anchor = "T4"
    for (m, b, p) in test:
        if b not in mid_batches:
            continue
        w = api.Workload(m, b, p)
        pair = oracle.minmax_cases(w, api.KNOB_BATCH, anchor)
        if pair is None:
            continue  # min/max config infeasible for this (model, pixel)
        lo_case, hi_case = pair
        for gt in PAPER_DEVICES:
            truth = ds.latency(gt, (m, b, p))
            # (a) true min/max measured on the target
            pa = oracle.interpolate(gt, api.KNOB_BATCH, b,
                                    ds.latency(gt, lo_case),
                                    ds.latency(gt, hi_case))
            true_mode[b].append((truth, pa))
            # (b) min/max predicted from the anchor profile (the oracle
            # chooses the min/max anchor configs itself)
            if gt != anchor:
                r = oracle.predict(api.PredictRequest(
                    anchor, gt, w, mode=api.MODE_TWO_PHASE,
                    knob=api.KNOB_BATCH))
                pred_mode[b].append((truth, r.latency_ms))

    def tab(d):
        return {b: {"mape": mape(*map(np.array, zip(*v))),
                    "n": len(v)} for b, v in d.items() if v}

    out = {"true_minmax": tab(true_mode), "pred_minmax": tab(pred_mode)}
    common.save("fig11", out)
    avg_true = np.mean([v["mape"] for v in out["true_minmax"].values()])
    avg_pred = np.mean([v["mape"] for v in out["pred_minmax"].values()])
    return {"true_minmax_avg_mape": avg_true,
            "pred_minmax_avg_mape": avg_pred}
