"""Fig 11: batch-size latency prediction for b in {32, 64, 128} with (a) TRUE
min/max latencies and (b) min/max PREDICTED by the cross-instance model."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core.devices import PAPER_DEVICES
from repro.core.ensemble import mape


def run() -> dict:
    ds = common.dataset().subset(PAPER_DEVICES)
    train, test = common.split()
    prophet = common.paper_profet()

    mid_batches = (32, 64, 128)
    true_mode = {b: [] for b in mid_batches}
    pred_mode = {b: [] for b in mid_batches}

    have = {c for c in ds.cases}
    anchor = "T4"
    for (m, b, p) in test:
        if b not in mid_batches:
            continue
        lo_case, hi_case = (m, 16, p), (m, 256, p)
        if lo_case not in have or hi_case not in have:
            continue  # min/max config infeasible for this (model, pixel)
        for gt in PAPER_DEVICES:
            truth = ds.latency(gt, (m, b, p))
            # (a) true min/max measured on the target
            t_lo = ds.latency(gt, lo_case)
            t_hi = ds.latency(gt, hi_case)
            pa = prophet.predict_knob(gt, "batch", b, t_lo, t_hi)
            true_mode[b].append((truth, float(pa)))
            # (b) min/max predicted from the anchor profile
            if gt != anchor:
                pb = prophet.predict_two_phase(
                    anchor, gt, "batch", b,
                    ds.profile(anchor, lo_case), ds.profile(anchor, hi_case),
                    case_min=lo_case, case_max=hi_case)
                pred_mode[b].append((truth, float(pb)))

    def tab(d):
        return {b: {"mape": mape(*map(np.array, zip(*v))),
                    "n": len(v)} for b, v in d.items() if v}

    out = {"true_minmax": tab(true_mode), "pred_minmax": tab(pred_mode)}
    common.save("fig11", out)
    avg_true = np.mean([v["mape"] for v in out["true_minmax"].values()])
    avg_pred = np.mean([v["mape"] for v in out["pred_minmax"].values()])
    return {"true_minmax_avg_mape": avg_true,
            "pred_minmax_avg_mape": avg_pred}
