"""Beyond-paper: the PROFET technique applied to TPU chip selection.

Cross-chip prediction across the TPU generations in the catalog (v4, v5e,
v5p) from GPU or TPU anchors, plus a cost advisor sweep: for each assigned
LM architecture's dry-run cell, combine the roofline step-time bound with
chip pricing to rank chips — the TPU analogue of the paper's Lambda demo.
"""
from __future__ import annotations

import json
import pathlib

import numpy as np

from benchmarks import common
from repro import api
from repro.core.devices import CATALOG, PAPER_DEVICES, TPU_DEVICES
from repro.core.ensemble import mape
from repro.core.predictor import ProfetConfig

DRYRUN = pathlib.Path("results/dryrun")


def run() -> dict:
    ds = common.dataset()
    train, test = common.split()

    # ---- cross-chip prophet: TPU anchors <-> TPU targets ----
    oracle = api.LatencyOracle.fit(
        ds, ProfetConfig(dnn_epochs=common.DNN_EPOCHS, seed=0), train,
        anchors=TPU_DEVICES + ("V100",), targets=TPU_DEVICES)
    cross = {}
    for ga in TPU_DEVICES + ("V100",):
        for gt in TPU_DEVICES:
            if ga == gt:
                continue
            pred = oracle.predict_cases(ga, gt, test)
            true = np.array([ds.latency(gt, c) for c in test])
            cross[f"{ga}->{gt}"] = mape(true, pred)

    # ---- dry-run-driven chip advisor for the assigned archs ----
    # scale the v5e roofline bound by peak-flops/bandwidth ratios per chip
    advisor = {}
    for f in sorted(DRYRUN.glob("*_train_4k_single.json")):
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            continue
        rl = r["roofline"]
        ranks = {}
        for chip in TPU_DEVICES:
            dev = CATALOG[chip]
            t = max(rl["hlo_flops_per_dev"] / (dev.peak_tflops * 1e12),
                    rl["hlo_bytes_per_dev"] / (dev.mem_bw_gbs * 1e9),
                    rl["t_collective_s"])      # ICI assumed equal
            ranks[chip] = {"step_s": t,
                           "cost_per_step": t / 3600 * dev.price_hr * 256}
        best = min(ranks, key=lambda c: ranks[c]["cost_per_step"])
        advisor[r["arch"]] = {"ranks": ranks, "cheapest": best}

    out = {"cross_chip_mape": cross, "advisor": advisor}
    common.save("tpu_advisor", out)
    cheap = {a: v["cheapest"] for a, v in advisor.items()}
    return {"avg_cross_chip_mape": float(np.mean(list(cross.values()))),
            "n_advised_archs": len(advisor),
            **{f"cheapest_{a}": c for a, c in list(cheap.items())[:3]}}
