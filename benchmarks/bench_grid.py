"""``predict_grid`` vectorization speedup: the advisor's hot path.

Baseline = the pre-``repro.api`` call pattern: one ``predict`` (one
``MedianEnsemble.predict`` on a (1, D) row) per grid cell per target.
Vectorized = one ``GridRequest``: a single feature matrix and ONE ensemble
call per (anchor, target) pair. Both run the same fitted oracle; results
must agree to float tolerance. Acceptance floor: >= 5x.

    PYTHONPATH=src python -m benchmarks.bench_grid           # full
    PYTHONPATH=src python -m benchmarks.bench_grid --smoke   # ~5 s CI gate
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro import api
from repro.core import workloads
from repro.core.predictor import ProfetConfig

TARGET_SPEEDUP = 5.0


def _fit_oracle(smoke: bool) -> api.LatencyOracle:
    if smoke:
        ds = workloads.generate(devices=("T4", "V100"),
                                models=("LeNet5", "AlexNet", "ResNet18"))
        cfg = ProfetConfig(members=("linear", "forest"), n_trees=30, seed=0)
    else:
        ds = workloads.generate(
            devices=("T4", "V100", "K80", "M60"),
            models=("LeNet5", "AlexNet", "ResNet18", "VGG11", "ResNet50",
                    "MobileNetV2"))
        cfg = ProfetConfig(dnn_epochs=40, n_trees=60, seed=0)
    return api.LatencyOracle.fit(ds, anchors=("T4",), config=cfg)


def _loop_baseline(oracle: api.LatencyOracle, req: api.GridRequest):
    """Per-cell prediction, exactly what callers hand-rolled before."""
    out = np.full((len(req.targets), len(req.batches), len(req.pixels)),
                  np.nan)
    for i, target in enumerate(req.targets):
        for j, b in enumerate(req.batches):
            for k, p in enumerate(req.pixels):
                try:
                    r = oracle.predict(api.PredictRequest(
                        req.anchor, target, api.Workload(req.model, b, p),
                        mode=(api.MODE_AUTO if target == req.anchor
                              else api.MODE_CROSS)))
                except api.ApiError:
                    continue
                out[i, j, k] = r.latency_ms
    return out


def run(smoke: bool = False) -> dict:
    oracle = _fit_oracle(smoke)
    req = api.GridRequest(
        anchor="T4", model="ResNet18",
        targets=("T4",) + oracle.targets_from("T4"),
        batches=tuple(workloads.BATCHES), pixels=tuple(workloads.PIXELS))

    # warm both paths once (jax dispatch caches, lazy tree packing)
    grid = oracle.predict_grid(req)
    loop = _loop_baseline(oracle, req)
    # rtol floor: the DNN member is float32, and batched vs per-row matmul
    # accumulate in different orders
    np.testing.assert_allclose(grid.latency_ms, loop, rtol=1e-5,
                               equal_nan=True)

    reps = 3
    t_loop = min(_timed(_loop_baseline, oracle, req, reps=reps))
    t_grid = min(_timed(oracle.predict_grid, req, reps=reps))
    n_cells = int(np.isfinite(grid.latency_ms).sum())
    speedup = t_loop / t_grid
    out = {"smoke": smoke, "n_cells": n_cells,
           "loop_ms": 1e3 * t_loop, "grid_ms": 1e3 * t_grid,
           "speedup": speedup, "target_speedup": TARGET_SPEEDUP}
    from benchmarks import common
    common.save("grid", out)
    return {"n_cells": n_cells, "loop_ms": out["loop_ms"],
            "grid_ms": out["grid_ms"], "speedup": speedup}


def _timed(fn, *args, reps: int):
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    return ts


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    smoke = "--smoke" in argv
    t0 = time.perf_counter()
    r = run(smoke=smoke)
    wall = time.perf_counter() - t0
    print(f"predict_grid: {r['n_cells']} cells  "
          f"loop {r['loop_ms']:.1f} ms  grid {r['grid_ms']:.1f} ms  "
          f"speedup {r['speedup']:.1f}x (target >= {TARGET_SPEEDUP:.0f}x)")
    from benchmarks import common
    ok = r["speedup"] >= TARGET_SPEEDUP
    common.save_bench("grid", speedup=r["speedup"], floor=TARGET_SPEEDUP,
                      wall_s=wall, passed=ok, smoke=smoke,
                      extra={"n_cells": r["n_cells"]})
    if not ok:
        print("FAIL: vectorized grid prediction under the speedup floor")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
