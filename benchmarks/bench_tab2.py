"""Table II: Joint vs Separate Modeling, same-information comparison.

Task: from ONE anchor profile of the workload's base config (batch 16, the
smallest feasible pixel size), predict the latency at (target instance,
target batch, target pixel).

  - Joint: a single model over [base profile ++ one-hot(target) ++ (b, p)].
  - Separate (PROFET): phase-1 cross-instance min/max prediction -> phase-2
    min-max poly interpolation, exactly the paper's two-model pipeline.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro import api
from repro.core.devices import PAPER_DEVICES
from repro.core.regressors import DNNRegressor, RandomForestRegressor

ANCHOR = "T4"
BASE_B = 16


def _base_case(have, m, p):
    return (m, BASE_B, p) if (m, BASE_B, p) in have else None


def _joint_xy(ds, oracle, cases, have):
    X, y = [], []
    dev_index = {d: i for i, d in enumerate(PAPER_DEVICES)}
    for (m, b, p) in cases:
        base = _base_case(have, m, p)
        if base is None:
            continue
        feats = oracle.features.transform(ds.profile(ANCHOR, base))
        for gt in PAPER_DEVICES:
            if gt == ANCHOR:
                continue
            onehot = np.zeros(len(PAPER_DEVICES))
            onehot[dev_index[gt]] = 1.0
            X.append(np.concatenate([feats, onehot, [b, p]]))
            y.append(ds.latency(gt, (m, b, p)))
    return np.stack(X), np.array(y)


def run() -> dict:
    ds = common.dataset().subset(PAPER_DEVICES)
    train, test = common.split()
    oracle = common.paper_oracle()
    have = set(ds.cases)

    Xtr, ytr = _joint_xy(ds, oracle, train, have)
    Xte, yte = _joint_xy(ds, oracle, test, have)

    joint = {}
    rf = RandomForestRegressor(n_estimators=60, seed=0).fit(Xtr, ytr)
    joint["RandomForest"] = common.metrics(yte, rf.predict(Xte))
    dnn = DNNRegressor(epochs=common.DNN_EPOCHS, seed=0).fit(Xtr, ytr)
    joint["DNN"] = common.metrics(yte, dnn.predict(Xte))

    # separate modeling (PROFET two-phase) on the same prediction task, one
    # column per phase-1 regressor family (the paper's RF/DNN columns). The
    # oracle picks the min/max anchor configs itself.
    from repro.core.predictor import ProfetConfig
    separate = {}
    for col, member in (("RandomForest", "forest"), ("DNN", "dnn")):
        o1 = api.LatencyOracle.fit(
            ds, ProfetConfig(dnn_epochs=common.DNN_EPOCHS, members=(member,)),
            train, anchors=(ANCHOR,), targets=PAPER_DEVICES)
        sep_true, sep_pred = [], []
        for (m, b, p) in test:
            w = api.Workload(m, b, p)
            if o1.minmax_cases(w, api.KNOB_BATCH, ANCHOR) is None:
                continue
            for gt in PAPER_DEVICES:
                if gt == ANCHOR:
                    continue
                r = o1.predict(api.PredictRequest(
                    ANCHOR, gt, w, mode=api.MODE_TWO_PHASE,
                    knob=api.KNOB_BATCH))
                sep_true.append(ds.latency(gt, (m, b, p)))
                sep_pred.append(r.latency_ms)
        separate[col] = common.metrics(np.array(sep_true),
                                       np.array(sep_pred))

    out = {"joint": joint, "separate": separate}
    common.save("tab2", out)
    return {"joint_dnn_mape": joint["DNN"]["mape"],
            "separate_dnn_mape": separate["DNN"]["mape"],
            "joint_rf_mape": joint["RandomForest"]["mape"],
            "separate_rf_mape": separate["RandomForest"]["mape"]}
