"""Benchmark suite runner: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run fig9_10    # one
"""
from __future__ import annotations

import sys
import time

BENCHES = [
    ("fig2", "benchmarks.bench_fig2", "Fig 2 latency/cost variation"),
    ("fig9_10", "benchmarks.bench_fig9_10", "Fig 9/10 cross-instance accuracy"),
    ("fig11", "benchmarks.bench_fig11", "Fig 11 batch-size predictor"),
    ("fig12", "benchmarks.bench_fig12", "Fig 12 poly order ablation"),
    ("tab2", "benchmarks.bench_tab2", "Table II joint vs separate"),
    ("fig13", "benchmarks.bench_fig13", "Fig 13 feature clustering"),
    ("tab3_4_5", "benchmarks.bench_tab3_4_5", "Tables III-V vs baselines"),
    ("tab6", "benchmarks.bench_tab6", "Table VI new devices"),
    ("grid", "benchmarks.bench_grid", "predict_grid vectorization speedup"),
    ("fit", "benchmarks.bench_fit", "Profet.fit vectorization speedup"),
    ("serve", "benchmarks.bench_serve", "fused predict_many vs predict loop"),
    ("transport", "benchmarks.bench_transport",
     "HTTP transport concurrent vs sequential clients"),
    ("bank", "benchmarks.bench_bank",
     "stacked ModelBank wave vs per-group dispatch"),
    ("calibrate", "benchmarks.bench_calibrate",
     "live calibration drift->refit->canary->promote recovery"),
    ("faults", "benchmarks.bench_faults",
     "fault-injected replay resilience floors (zero lost requests)"),
    ("shard", "benchmarks.bench_shard",
     "multi-worker sharded wave execution vs single-worker bank"),
    ("multihost", "benchmarks.bench_multihost",
     "TCP-loopback multi-host shard plane vs single-worker bank"),
    ("recovery", "benchmarks.bench_recovery",
     "self-healing worker recovery: post-adoption throughput restoration"),
    ("roofline", "benchmarks.bench_roofline", "Roofline table (dry-run)"),
    ("perf", "benchmarks.bench_perf", "Perf before/after (dry-run)"),
    ("serving", "benchmarks.bench_serve:run_engine",
     "Continuous vs wave batching (token engine)"),
    ("tpu_advisor", "benchmarks.bench_tpu_advisor", "TPU cross-chip advisor"),
]


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    only = set(argv)
    failures = 0
    print("benchmark,seconds,summary")
    for name, module, desc in BENCHES:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            import importlib
            mod_name, _, attr = module.partition(":")
            mod = importlib.import_module(mod_name)
            summary = getattr(mod, attr or "run")()
            dt = time.time() - t0
            pretty = " ".join(f"{k}={v:.3f}" if isinstance(v, float)
                              else f"{k}={v}" for k, v in summary.items())
            print(f"{name},{dt:.1f},{pretty}", flush=True)
        except Exception as e:  # pragma: no cover
            failures += 1
            import traceback
            traceback.print_exc()
            print(f"{name},FAILED,{type(e).__name__}: {e}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
