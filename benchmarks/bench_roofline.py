"""§Roofline: the three-term roofline table for every (arch x shape x mesh)
dry-run cell, read from results/dryrun/*.json (produced by repro.launch.dryrun).
"""
from __future__ import annotations

import json
import pathlib

from benchmarks import common

DRYRUN = pathlib.Path("results/dryrun")


def rows(mesh: str = None):
    out = []
    for f in sorted(DRYRUN.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("status") != "ok" or "roofline" not in r:
            continue
        if mesh and r["mesh"] != mesh:
            continue
        out.append(r)
    return out


def run() -> dict:
    recs = rows()
    if not recs:
        print("  (no dry-run artifacts found — run repro.launch.dryrun first)")
        return {"cells": 0}

    table = []
    for r in recs:
        rl = r["roofline"]
        table.append([
            r["arch"], r["shape"], r["mesh"],
            f"{rl['t_compute_s']*1e3:.2f}",
            f"{rl['t_memory_s']*1e3:.2f}",
            f"{rl['t_collective_s']*1e3:.2f}",
            rl["bottleneck"],
            f"{rl['useful_flops_ratio']:.3f}",
            f"{rl['roofline_fraction']:.4f}",
            f"{rl.get('per_device_memory', 0)/2**30:.1f}",
        ])
    headers = ["arch", "shape", "mesh", "t_comp_ms", "t_mem_ms", "t_coll_ms",
               "bottleneck", "useful_flops", "roofline_frac", "GiB/dev"]
    print(common.fmt_table(table, headers))

    singles = [r for r in recs if r["mesh"] == "single"]
    bottlenecks = {}
    for r in singles:
        b = r["roofline"]["bottleneck"]
        bottlenecks[b] = bottlenecks.get(b, 0) + 1
    common.save("roofline", {"table": table, "headers": headers,
                             "bottleneck_histogram": bottlenecks})
    return {"cells": len(recs), "single_pod_cells": len(singles),
            **{f"bottleneck_{k}": v for k, v in bottlenecks.items()}}
