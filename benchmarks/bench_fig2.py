"""Fig 2: CNN training latency/cost variation across GPU cloud instances.

(a) LeNet5 vs AlexNet across instances (latency normalized to the best;
    relative cost), (b) ResNet50 at 32 vs 128 px, (c) batch-scaling ratio
    quantiles per instance — the non-linearity that motivates the order-2
    knob model.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import workloads
from repro.core.devices import CATALOG, PAPER_DEVICES


def run() -> dict:
    ds = common.dataset()

    def lat(d, case):
        return ds.latency(d, case)

    # --- (a) model x instance ---
    fig2a = {}
    for model, batch, pix in (("LeNet5", 16, 32), ("AlexNet", 16, 32)):
        lats = {d: lat(d, (model, batch, pix)) for d in PAPER_DEVICES}
        best = min(lats.values())
        fig2a[model] = {
            d: {"latency_ms": lats[d], "norm_latency": lats[d] / best,
                "rel_cost": lats[d] * CATALOG[d].price_hr} for d in lats}

    # --- (b) ResNet50 pixel sizes ---
    fig2b = {}
    for pix in (32, 128):
        lats = {d: lat(d, ("ResNet50", 16, pix)) for d in PAPER_DEVICES}
        fig2b[f"pix{pix}"] = {
            d: {"latency_ms": lats[d],
                "cost_per_1k_batches": lats[d] / 3.6e6 * 1e3
                * CATALOG[d].price_hr} for d in lats}

    # --- (c) batch scaling ratio quantiles per instance ---
    fig2c = {}
    for d in PAPER_DEVICES:
        ratios = []
        for (m, b, p) in ds.cases:
            if b == 16:
                continue
            base = (m, 16, p)
            if base in ds.measurements[d]:
                ratios.append(lat(d, (m, b, p)) / lat(d, base))
        q = np.quantile(ratios, [0.0, 0.25, 0.5, 0.75, 1.0])
        fig2c[d] = {"min": q[0], "p25": q[1], "median": q[2], "p75": q[3],
                    "max": q[4]}

    # headline phenomena the paper calls out
    mob = [lat("V100", ("MobileNetV2", b, 32)) for b in (16, 256)]
    vgg = [lat("T4", ("VGG13", b, 128)) for b in (16, 256)]
    summary = {
        "alexnet_best_worst_spread":
            max(v["norm_latency"] for v in fig2a["AlexNet"].values()),
        "mobilenet_v100_16x_batch_ratio": mob[1] / mob[0],
        "vgg13_t4_16x_batch_ratio": vgg[1] / vgg[0],
    }
    out = {"fig2a": fig2a, "fig2b": fig2b, "fig2c": fig2c,
           "summary": summary}
    common.save("fig2", out)
    return summary
