"""Tables III-V: PROFET vs Paleo, MLPredict, Habitat (all re-implemented in
``repro.core.baselines`` — see DESIGN.md §7)."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import baselines
from repro.core.devices import PAPER_DEVICES
from repro.core.ensemble import mape, r2, rmse


def run() -> dict:
    ds = common.dataset().subset(PAPER_DEVICES)
    train, test = common.split()
    oracle = common.paper_oracle()

    # ---- Table III: vs Paleo on the common models (AlexNet, VGG16) ----
    pa = baselines.PaleoModel()
    for d in PAPER_DEVICES:
        pa.calibrate_many(d, train, [ds.latency(d, c) for c in train])
    t3_cases = [c for c in test if c[0] in ("AlexNet", "VGG16")]
    paleo_pred = np.array([pa.predict(d, c)
                           for d in PAPER_DEVICES for c in t3_cases])
    t3_true = np.array([ds.latency(d, c)
                        for d in PAPER_DEVICES for c in t3_cases])
    profet_t3_pred, profet_t3_true = [], []
    for gt in PAPER_DEVICES:
        for ga in PAPER_DEVICES:
            if ga == gt:
                continue
            profet_t3_pred.append(oracle.predict_cases(ga, gt, t3_cases))
            profet_t3_true.append([ds.latency(gt, c) for c in t3_cases])
            break  # one anchor per target (the paper's protocol)
    tab3 = {"PALEO": common.metrics(t3_true, paleo_pred),
            "PROFET": common.metrics(np.concatenate(profet_t3_true),
                                     np.concatenate(profet_t3_pred))}

    # ---- Table IV: vs MLPredict, VGG16 by batch size ----
    ml = baselines.MLPredictModel(epochs=common.DNN_EPOCHS, seed=0)
    ml.fit(ds, train)
    tab4 = {}
    for b in (16, 32, 64, 128):
        cases_b = [c for c in ds.cases if c[0] == "VGG16" and c[1] == b]
        if not cases_b:
            continue
        true = np.array([ds.latency(d, c)
                         for d in PAPER_DEVICES for c in cases_b])
        ml_pred = np.array([ml.predict(d, c)
                            for d in PAPER_DEVICES for c in cases_b])
        pf_pred, pf_true = [], []
        for gt in PAPER_DEVICES:
            ga = "T4" if gt != "T4" else "V100"
            pf_pred.append(oracle.predict_cases(ga, gt, cases_b))
            pf_true.append([ds.latency(gt, c) for c in cases_b])
        tab4[b] = {
            "MLPredict": {"mape": mape(true, ml_pred),
                          "rmse": rmse(true, ml_pred)},
            "PROFET": {"mape": mape(np.concatenate(pf_true),
                                    np.concatenate(pf_pred)),
                       "rmse": rmse(np.concatenate(pf_true),
                                    np.concatenate(pf_pred))}}

    # ---- Table V: vs Habitat, T4 <-> V100 on 3 models ----
    hb = baselines.HabitatScaling()
    t5_models = ("ResNet50", "InceptionV3", "VGG16")
    tab5 = {}
    for ga, gt in (("T4", "V100"), ("V100", "T4")):
        cases5 = [c for c in test if c[0] in t5_models]
        true = np.array([ds.latency(gt, c) for c in cases5])
        hb_pred = np.array([hb.predict(ga, gt, c) for c in cases5])
        pf_pred = oracle.predict_cases(ga, gt, cases5)
        tab5[f"{ga}->{gt}"] = {"Habitat": mape(true, hb_pred),
                               "PROFET": mape(true, pf_pred)}

    out = {"tab3": tab3, "tab4": tab4, "tab5": tab5}
    common.save("tab3_4_5", out)

    t4_impr = np.mean([1 - tab4[b]["PROFET"]["rmse"]
                       / tab4[b]["MLPredict"]["rmse"] for b in tab4])
    t5_impr = np.mean([1 - v["PROFET"] / v["Habitat"]
                       for v in tab5.values()])
    return {
        "tab3_paleo_mape": tab3["PALEO"]["mape"],
        "tab3_profet_mape": tab3["PROFET"]["mape"],
        "tab4_rmse_improvement_vs_mlpredict_pct": 100 * float(t4_impr),
        "tab5_mape_improvement_vs_habitat_pct": 100 * float(t5_impr),
    }
