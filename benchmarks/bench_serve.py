"""Fused ``predict_many`` vs the per-request ``predict`` loop on a mixed
request stream — the serving layer's hot path.

Baseline = one plan + execute round-trip per request (what a naive HTTP
handler would do). Fused = ONE ``predict_many`` over the same shuffled
stream: rows dedup per anchor, one ensemble call per (anchor, target) pair,
two-phase interpolation vectorized per (target, knob). Both run the same
fitted oracle; results must agree element-wise. Acceptance floor: >= 5x.

    PYTHONPATH=src python -m benchmarks.bench_serve           # full
    PYTHONPATH=src python -m benchmarks.bench_serve --smoke   # CI gate
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro import api
from repro.core import workloads
from repro.core.predictor import ProfetConfig
from repro.serve import synthetic_requests

TARGET_SPEEDUP = 5.0
N_REQUESTS = 500


def _fit_oracle(smoke: bool) -> api.LatencyOracle:
    if smoke:
        ds = workloads.generate(devices=("T4", "V100"),
                                models=("LeNet5", "AlexNet", "ResNet18"))
        cfg = ProfetConfig(members=("linear", "forest"), n_trees=30, seed=0)
    else:
        ds = workloads.generate(
            devices=("T4", "V100", "K80", "M60"),
            models=("LeNet5", "AlexNet", "ResNet18", "VGG11", "ResNet50",
                    "MobileNetV2"))
        cfg = ProfetConfig(dnn_epochs=40, n_trees=60, seed=0)
    return api.LatencyOracle.fit(ds, config=cfg)


def _loop_baseline(oracle: api.LatencyOracle, reqs):
    return [oracle.predict(r) for r in reqs]


def run(smoke: bool = False) -> dict:
    oracle = _fit_oracle(smoke)
    reqs = synthetic_requests(oracle, n=N_REQUESTS, seed=0)

    # warm both paths once (jax dispatch caches, lazy tree packing) and
    # assert element-wise agreement of the fused and sequential answers
    fused = oracle.predict_many(reqs)
    seq = _loop_baseline(oracle, reqs)
    # float64 members are exact; the float32 DNN member batches its matmul
    rtol = 1e-9 if smoke else 1e-5
    np.testing.assert_allclose(fused.latencies(),
                               [r.latency_ms for r in seq], rtol=rtol)
    assert [r.mode for r in fused] == [r.mode for r in seq]
    assert [r.price_hr for r in fused] == [r.price_hr for r in seq]

    reps = 3
    t_loop = min(_timed(_loop_baseline, oracle, reqs, reps=reps))
    t_fused = min(_timed(oracle.predict_many, reqs, reps=reps))
    speedup = t_loop / t_fused
    out = {"smoke": smoke, "n_requests": len(reqs),
           "fused_calls": fused.fused_calls, "rows": fused.rows,
           "modes": dict(fused.mode_counts),
           "loop_ms": 1e3 * t_loop, "fused_ms": 1e3 * t_fused,
           "speedup": speedup, "target_speedup": TARGET_SPEEDUP}
    from benchmarks import common
    common.save("serve", out)
    return {"n_requests": len(reqs), "fused_calls": fused.fused_calls,
            "loop_ms": out["loop_ms"], "fused_ms": out["fused_ms"],
            "speedup": speedup}


def _timed(fn, *args, reps: int):
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    return ts


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    smoke = "--smoke" in argv
    r = run(smoke=smoke)
    print(f"predict_many: {r['n_requests']} mixed requests -> "
          f"{r['fused_calls']} fused calls  "
          f"loop {r['loop_ms']:.1f} ms  fused {r['fused_ms']:.1f} ms  "
          f"speedup {r['speedup']:.1f}x (target >= {TARGET_SPEEDUP:.0f}x)")
    if r["speedup"] < TARGET_SPEEDUP:
        print("FAIL: fused batched prediction under the speedup floor")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
