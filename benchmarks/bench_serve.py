"""Fused ``predict_many`` vs the per-request ``predict`` loop on a mixed
request stream — the serving layer's hot path.

Baseline = one plan + execute round-trip per request (what a naive HTTP
handler would do). Fused = ONE ``predict_many`` over the same shuffled
stream: rows dedup per anchor, one ensemble call per (anchor, target) pair,
two-phase interpolation vectorized per (target, knob). Both run the same
fitted oracle; results must agree element-wise. Acceptance floor: >= 5x.

    PYTHONPATH=src python -m benchmarks.bench_serve           # full
    PYTHONPATH=src python -m benchmarks.bench_serve --smoke   # CI gate

``run_engine`` (the ``serving`` entry in ``benchmarks.run``) is the token
engine's sibling comparison — continuous (inflight) batching vs wave-aligned
static batching on a mixed-length trace — folded in here from the retired
``bench_serving.py`` and driven through the public ``repro.serve.Engine``
surface.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro import api
from repro.core import workloads
from repro.core.predictor import ProfetConfig
from repro.serve import synthetic_requests

TARGET_SPEEDUP = 5.0
N_REQUESTS = 500


def _fit_oracle(smoke: bool) -> api.LatencyOracle:
    if smoke:
        ds = workloads.generate(devices=("T4", "V100"),
                                models=("LeNet5", "AlexNet", "ResNet18"))
        cfg = ProfetConfig(members=("linear", "forest"), n_trees=30, seed=0)
    else:
        ds = workloads.generate(
            devices=("T4", "V100", "K80", "M60"),
            models=("LeNet5", "AlexNet", "ResNet18", "VGG11", "ResNet50",
                    "MobileNetV2"))
        cfg = ProfetConfig(dnn_epochs=40, n_trees=60, seed=0)
    return api.LatencyOracle.fit(ds, config=cfg)


def _loop_baseline(oracle: api.LatencyOracle, reqs):
    return [oracle.predict(r) for r in reqs]


def run(smoke: bool = False) -> dict:
    oracle = _fit_oracle(smoke)
    reqs = synthetic_requests(oracle, n=N_REQUESTS, seed=0)

    # warm both paths once (jax dispatch caches, lazy tree packing) and
    # assert element-wise agreement of the fused and sequential answers
    fused = oracle.predict_many(reqs)
    seq = _loop_baseline(oracle, reqs)
    # float64 members are exact; the float32 DNN member batches its matmul
    rtol = 1e-9 if smoke else 1e-5
    np.testing.assert_allclose(fused.latencies(),
                               [r.latency_ms for r in seq], rtol=rtol)
    assert [r.mode for r in fused] == [r.mode for r in seq]
    assert [r.price_hr for r in fused] == [r.price_hr for r in seq]

    reps = 3
    t_loop = min(_timed(_loop_baseline, oracle, reqs, reps=reps))
    t_fused = min(_timed(oracle.predict_many, reqs, reps=reps))
    speedup = t_loop / t_fused
    out = {"smoke": smoke, "n_requests": len(reqs),
           "fused_calls": fused.fused_calls, "rows": fused.rows,
           "modes": dict(fused.mode_counts),
           "loop_ms": 1e3 * t_loop, "fused_ms": 1e3 * t_fused,
           "speedup": speedup, "target_speedup": TARGET_SPEEDUP}
    from benchmarks import common
    common.save("serve", out)
    return {"n_requests": len(reqs), "fused_calls": fused.fused_calls,
            "loop_ms": out["loop_ms"], "fused_ms": out["fused_ms"],
            "speedup": speedup}


def _timed(fn, *args, reps: int):
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    return ts


# ---------------------------------------------------------------------------
# token engine: continuous vs wave batching (REAL measurements on the CPU
# device, smoke configs) — the beyond-paper serving deliverable, through
# the public repro.serve.Engine surface
# ---------------------------------------------------------------------------

ENGINE_ARCHS = ("llama3_2_1b", "mamba2_130m")


def _engine_trace(rng, n=10):
    """Mixed prompt/output lengths — the case wave scheduling handles
    worst."""
    return [(rng.integers(2, 24, endpoint=True),
             rng.integers(2, 10, endpoint=True)) for _ in range(n)]


def _run_engine_mode(Engine, cfg, params, mode, trace):
    eng = Engine(cfg, params, batch_slots=4, max_len=96, mode=mode)
    rng = np.random.default_rng(0)
    reqs = []
    for plen, n_new in trace:
        prompt = rng.integers(1, 200, size=int(plen)).tolist()
        reqs.append(eng.submit(prompt, max_new_tokens=int(n_new)))
    t0 = time.perf_counter()
    eng.run()
    wall = time.perf_counter() - t0
    lat = [r.t_finish - r.t_submit for r in reqs]
    return {"wall_s": wall,
            "tokens_per_s": eng.stats.generated_tokens / wall,
            "decode_steps": eng.stats.decode_steps,
            "p50_latency_s": float(np.median(lat)),
            "p99_latency_s": float(np.quantile(lat, 0.99))}


def run_engine() -> dict:
    # jax + the model stack load lazily so the latency-serving gate above
    # stays light
    import jax

    from repro.configs import base as CB
    from repro.models import model as M
    from repro.serve import Engine

    rng = np.random.default_rng(7)
    trace = _engine_trace(rng)
    out = {}
    for arch in ENGINE_ARCHS:
        cfg = CB.get_config(arch, smoke=True)
        params, _ = M.init(jax.random.PRNGKey(0), cfg)
        # warm the jit once so compilation doesn't skew either mode
        warm = Engine(cfg, params, batch_slots=4, max_len=96)
        warm.submit([1, 2], max_new_tokens=2)
        warm.run()
        out[arch] = {m: _run_engine_mode(Engine, cfg, params, m, trace)
                     for m in ("continuous", "wave")}
    from benchmarks import common
    common.save("serving", out)
    summary = {}
    for arch, modes in out.items():
        speed = (modes["continuous"]["tokens_per_s"]
                 / modes["wave"]["tokens_per_s"])
        steps = (modes["wave"]["decode_steps"]
                 / max(modes["continuous"]["decode_steps"], 1))
        summary[f"{arch}_throughput_gain"] = speed
        summary[f"{arch}_step_reduction"] = steps
    return summary


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    smoke = "--smoke" in argv
    t0 = time.perf_counter()
    r = run(smoke=smoke)
    wall = time.perf_counter() - t0
    print(f"predict_many: {r['n_requests']} mixed requests -> "
          f"{r['fused_calls']} fused calls  "
          f"loop {r['loop_ms']:.1f} ms  fused {r['fused_ms']:.1f} ms  "
          f"speedup {r['speedup']:.1f}x (target >= {TARGET_SPEEDUP:.0f}x)")
    from benchmarks import common
    ok = r["speedup"] >= TARGET_SPEEDUP
    common.save_bench("serve", speedup=r["speedup"], floor=TARGET_SPEEDUP,
                      wall_s=wall, passed=ok, smoke=smoke,
                      extra={"fused_calls": r["fused_calls"]})
    if not ok:
        print("FAIL: fused batched prediction under the speedup floor")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
